"""Continuous-batching serving scheduler.

Production serving does not run prefill/decode on fixed request batches: it
keeps a fixed number of SLOTS (the compiled decode batch size), admits new
requests into free slots as running ones finish, and runs one fused decode
step per tick for whatever is resident.  That keeps the compiled decode
shape static (one XLA program) while the request mix churns — the same
design as production LLM servers, adapted to this framework's
``ServeState``.

Mechanics:

- One decode program of batch = ``num_slots`` is compiled once.  Empty
  slots carry a pad token and their outputs are ignored.
- Prefill runs per admitted request (batch 1) and its cache is scattered
  into the slot's rows of the shared stacked cache.
- Per-request stopping: max_new_tokens or an EOS token id.
- Fairness/occupancy stats for capacity planning.

The scatter uses ``jax.tree.map`` over the cache pytree with a dynamic
batch-row update — O(cache_row) per admission, no recompile.
"""

from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import PagedKVCache
from repro.models.model import Model
from repro.obs import trace as obs_trace
from repro.serving.paging import PagedPlan
from repro.train.serve_step import ServeState, jitted_steps, sample_token
from repro.utils.config import RunConfig


class PromptTooLong(ValueError):
    """A submitted request can never fit its serving deployment: prompt plus
    worst-case generation exceeds the dense ``cache_len`` or the paged slot
    capacity / page pool.  Carries the offending request uid and the limit so
    callers can report or reject-and-count (``on_too_long="reject"``)."""

    def __init__(self, uid: int, needed: int, limit: int, what: str):
        super().__init__(
            f"request {uid} needs {needed} cache tokens but the {what} "
            f"holds {limit}; it would silently truncate — reject it or "
            f"deploy a larger geometry")
        self.uid = uid
        self.needed = needed
        self.limit = limit


class DrainStall(RuntimeError):
    """A drain loop (real scheduler or the workload simulator) hit its tick
    budget with requests still queued or resident — a stall, not a completed
    run.  Carries the progress made so callers can report it."""

    def __init__(self, msg: str, *, completed: int, pending: int):
        super().__init__(msg)
        self.completed = completed
        self.pending = pending


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    extras: Dict[str, np.ndarray] = field(default_factory=dict)


@dataclass
class RequestState:
    request: Request
    slot: int
    generated: List[int] = field(default_factory=list)
    admitted_at: float = 0.0
    finished_at: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finished_at is not None


def _scatter_rows(dst_tree, src_tree, slot: int):
    """Write src (batch-1 state rows) into dst at batch row `slot`.

    Cache leaves are stacked (layers, batch, ...); lengths are (batch,).
    The batch dim is located as the first axis whose size equals the slot
    count — for stacked leaves that is axis 1, for flat leaves axis 0.
    """
    def one(dst, src):
        if dst.ndim == src.ndim and dst.shape == src.shape:
            return dst  # shared/static (e.g. vision_kv broadcast) — keep
        if dst.ndim >= 2 and src.ndim == dst.ndim and \
                src.shape[0] == dst.shape[0] and src.shape[1] == 1:
            # stacked (layers, 1, ...) -> row `slot` of (layers, B, ...)
            return jax.lax.dynamic_update_slice_in_dim(dst, src, slot, axis=1)
        if src.ndim == dst.ndim and src.shape[0] == 1:
            return jax.lax.dynamic_update_slice_in_dim(dst, src, slot, axis=0)
        raise ValueError(f"unscatterable leaf {src.shape} -> {dst.shape}")

    return jax.tree.map(one, dst_tree, src_tree)


def _scatter_paged_rows(dst_tree, src_tree, slot: int, pages: List[int],
                        page_size: int, pages_per_slot_max: int,
                        scratch_page: int):
    """Write a dense batch-1 prefill state into slot ``slot`` of a paged
    decode state: KV rows land in the slot's reserved pool ``pages`` (the
    first ``len(pages) * page_size`` dense rows, page-reshaped), the page
    table row is rewritten wholesale (tail entries pinned to the scratch
    page — valid and owned by nobody), and recurrent (SSM) leaves scatter
    exactly like the dense path."""
    table_row = np.full((pages_per_slot_max,), scratch_page, np.int32)
    table_row[:len(pages)] = pages
    table_row = jnp.asarray(table_row)
    pages_arr = jnp.asarray(pages, jnp.int32)

    def one(dst, src):
        if isinstance(dst, PagedKVCache):
            n = len(pages)
            nsb = src.k.shape[0]
            rows = src.k[:, 0, :n * page_size]
            rows = rows.reshape(nsb, n, page_size, *rows.shape[2:])
            k_pages = dst.k_pages.at[:, pages_arr].set(rows)
            rows = src.v[:, 0, :n * page_size]
            rows = rows.reshape(nsb, n, page_size, *rows.shape[2:])
            v_pages = dst.v_pages.at[:, pages_arr].set(rows)
            table = dst.page_table.at[:, slot].set(table_row[None])
            length = dst.length.at[:, slot].set(src.length[:, 0])
            return PagedKVCache(k_pages, v_pages, table, length)
        return _scatter_rows(dst, src, slot)

    return jax.tree.map(one, dst_tree, src_tree,
                        is_leaf=lambda x: isinstance(x, PagedKVCache))


class ContinuousBatcher:
    def __init__(self, model: Model, run: RunConfig, params, *,
                 num_slots: int = 8, cache_len: int = 512,
                 eos_token: Optional[int] = None, seed: int = 0,
                 launch_config: Optional[Dict[str, Any]] = None,
                 interleave: str = "eager",
                 paged: Optional[PagedPlan] = None,
                 on_too_long: str = "raise"):
        if interleave not in ("eager", "drain"):
            raise ValueError(
                f"unknown interleave policy {interleave!r}; "
                f"known: ['drain', 'eager']")
        if on_too_long not in ("raise", "reject"):
            raise ValueError(f"on_too_long must be 'raise' or 'reject', "
                             f"got {on_too_long!r}")
        self.model = model
        self.run = run
        self.params = params
        self.num_slots = num_slots
        self.eos_token = eos_token
        self.interleave = interleave
        self.on_too_long = on_too_long
        self._key = jax.random.PRNGKey(seed)

        self.paged = paged if (paged is not None and paged.paging) else None
        if self.paged is not None:
            if model.init_paged_decode_state is None:
                raise NotImplementedError(
                    f"model family {model.cfg.family!r} has no paged decode "
                    f"state; serve it dense (pages.paging=off)")
            # the compiled decode shape is the (pool, page) geometry — the
            # per-slot capacity is a page-table property, not a cache axis,
            # so `cache_len` is superseded by page_size * pages_per_slot_max
            self.cache_len = self.paged.slot_capacity
            caches = model.init_paged_decode_state(
                num_slots, self.paged.pool_pages, self.paged.page_size,
                self.paged.pages_per_slot_max)
            self._free_pages: List[int] = list(range(self.paged.pool_pages))
            self._slot_pages: List[List[int]] = [[] for _ in range(num_slots)]
        else:
            self.cache_len = cache_len
            caches = model.init_decode_state(num_slots, cache_len)

        # a tuned kernel-launch optimum (e.g. TuneResult.launch_config) is
        # baked into the traces; the shared cache means several batchers on
        # one model reuse the compilation.  Prefill always runs dense — for
        # paged deployments at the slot capacity, then page-scattered.
        self._prefill, self._decode = jitted_steps(
            model, run, cache_len=self.cache_len, launch_config=launch_config)

        self.state = ServeState(
            caches=caches,
            lengths=jnp.zeros((num_slots,), jnp.int32),
            extras={})
        self._tokens = jnp.zeros((num_slots,), jnp.int32)
        self._slots: List[Optional[RequestState]] = [None] * num_slots
        self.queue: List[Request] = []
        self.completed: List[RequestState] = []
        # chunked prefill in flight: [request, tokens_done, slot, pages]
        self._prefilling: Optional[List[Any]] = None
        self.rejected_too_long = 0
        self.prefill_chunks = 0
        self.ticks = 0
        self.stalled = False
        self._occupancy_sum = 0
        # per-decode-tick paged mediators (mirror the simulator's counters)
        self._pool_occ_sum = 0.0
        self._chunks_inflight_sum = 0.0
        # lifetime wall time inside prefill vs decode launches — replay
        # reports diff these to get a per-replay prefill/decode split
        self.prefill_s = 0.0
        self.decode_s = 0.0
        # request-lifecycle tracing: submit timestamps (tracer us) per uid,
        # populated only while a tracer is active — the disabled path never
        # touches it, so tokens/counters stay bit-identical
        self._submit_ts: Dict[int, float] = {}

    # -- admission ----------------------------------------------------------

    def _worst_case_tokens(self, request: Request) -> int:
        """Cache rows this request can ever occupy: the prompt plus every
        decode-tick write (the first token is sampled from prefill and costs
        no extra row)."""
        return len(request.prompt) + max(request.max_new_tokens - 1, 0)

    def submit(self, request: Request) -> None:
        """Enqueue a request, rejecting (or raising, per ``on_too_long``) any
        that could never fit the deployed geometry — dense caches silently
        drop overflow rows, which corrupts decoding rather than failing."""
        needed = self._worst_case_tokens(request)
        if self.paged is not None:
            limit = min(self.paged.slot_capacity,
                        self.paged.pool_pages * self.paged.page_size)
            what = "paged slot"
        else:
            limit = self.cache_len
            what = "dense cache"
        if needed > limit:
            if self.on_too_long == "raise":
                raise PromptTooLong(request.uid, needed, limit, what)
            self.rejected_too_long += 1
            tr = obs_trace.active()
            if tr is not None:
                tr.instant("reject_too_long", cat="request",
                           uid=request.uid, needed=needed, limit=limit)
            return
        tr = obs_trace.active()
        if tr is not None:
            self._submit_ts[request.uid] = tr.now_us()
            tr.async_begin("request", request.uid,
                           prompt_len=len(request.prompt),
                           max_new=request.max_new_tokens)
        self.queue.append(request)

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def _prefill_and_seat(self, req: Request, slot: int,
                          pages: Optional[List[int]]) -> None:
        """Run the (dense, batch-1) prefill and seat the request in ``slot``
        — scattered into its reserved ``pages`` for paged deployments."""
        tr = obs_trace.active()
        if tr is not None:
            # admission closes the queue phase begun at submit
            sub_ts = self._submit_ts.pop(req.uid, None)
            if sub_ts is not None:
                tr.complete("queue", sub_ts, tr.now_us() - sub_ts,
                            cat="request", uid=req.uid)
            tr.instant("admit", cat="request", uid=req.uid, slot=slot,
                       pages=len(pages) if pages is not None else 0)
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        batch = {"tokens": prompt}
        for k, v in req.extras.items():
            batch[k] = jnp.asarray(v)[None]
        t0 = time.perf_counter()
        with obs_trace.span("prefill", cat="request", uid=req.uid,
                            prompt_len=len(req.prompt)):
            one_state, logits = self._prefill(self.params, batch)
            jax.block_until_ready(logits)
        self.prefill_s += time.perf_counter() - t0
        if pages is not None:
            caches = _scatter_paged_rows(
                self.state.caches, one_state.caches, slot, pages,
                self.paged.page_size, self.paged.pages_per_slot_max,
                scratch_page=self.paged.pool_pages)
        else:
            caches = _scatter_rows(self.state.caches, one_state.caches, slot)
        self.state = ServeState(
            caches=caches,
            lengths=self.state.lengths.at[slot].set(one_state.lengths[0]),
            extras=self.state.extras)
        self._key, sub = jax.random.split(self._key)
        tok = int(sample_token(logits, sub, req.temperature)[0])
        rs = RequestState(req, slot, admitted_at=time.perf_counter())
        rs.generated.append(tok)
        self._tokens = self._tokens.at[slot].set(tok)
        self._slots[slot] = rs
        self._maybe_finish(rs, tok)

    def _admit(self) -> None:
        if self.interleave == "drain" and \
                any(s is not None for s in self._slots):
            # drain policy: only refill once the resident batch empties —
            # the same admission gate the workload simulator prices
            return
        if self.paged is not None and self.paged.prefill_chunk > 0:
            self._admit_chunked()
            return
        for slot in self._free_slots():
            if not self.queue:
                break
            if self.paged is not None:
                # reserve the worst case up front: unlike the simulator the
                # real batcher never grows a resident mid-flight (and so
                # never evicts) — exhausted pool defers admission instead
                need = self.paged.pages_for(
                    self._worst_case_tokens(self.queue[0]))
                if need > len(self._free_pages):
                    obs_trace.instant("defer", cat="request",
                                      uid=self.queue[0].uid, need=need,
                                      free=len(self._free_pages))
                    break
                pages = [self._free_pages.pop(0) for _ in range(need)]
                self._slot_pages[slot] = pages
                obs_trace.instant("page_reserve", cat="request",
                                  uid=self.queue[0].uid, pages=need,
                                  free=len(self._free_pages))
            else:
                pages = None
            req = self.queue.pop(0)
            self._prefill_and_seat(req, slot, pages)

    def _admit_chunked(self) -> None:
        """Chunked-prefill admission: one prompt chunk per tick, decode
        ticking underneath.  The jitted prefill still runs once, over the
        full prompt, when the last chunk lands — chunking is a *scheduling*
        decision (when prefill work occupies the accelerator), so generated
        tokens stay bit-identical to the unchunked batcher."""
        if self._prefilling is not None:
            req, done, slot, pages = self._prefilling
            done += min(self.paged.prefill_chunk, len(req.prompt) - done)
            self.prefill_chunks += 1
            obs_trace.instant("prefill_chunk", cat="request", uid=req.uid,
                              done=done, prompt_len=len(req.prompt))
            if done >= len(req.prompt):
                self._prefilling = None
                self._prefill_and_seat(req, slot, pages)
            else:
                self._prefilling[1] = done
            return
        free = self._free_slots()
        if not self.queue or not free:
            return
        need = self.paged.pages_for(self._worst_case_tokens(self.queue[0]))
        if need > len(self._free_pages):
            obs_trace.instant("defer", cat="request", uid=self.queue[0].uid,
                              need=need, free=len(self._free_pages))
            return
        slot = free[0]
        pages = [self._free_pages.pop(0) for _ in range(need)]
        self._slot_pages[slot] = pages
        obs_trace.instant("page_reserve", cat="request",
                          uid=self.queue[0].uid, pages=need,
                          free=len(self._free_pages))
        self._prefilling = [self.queue.pop(0), 0, slot, pages]

    # -- stepping -----------------------------------------------------------

    def _maybe_finish(self, rs: RequestState, tok: int) -> None:
        if rs.done:
            return
        if (self.eos_token is not None and tok == self.eos_token) or \
                len(rs.generated) >= rs.request.max_new_tokens:
            rs.finished_at = time.perf_counter()
            self.completed.append(rs)
            self._slots[rs.slot] = None
            tr = obs_trace.active()
            if tr is not None:
                tr.instant("retire", cat="request", uid=rs.request.uid,
                           generated=len(rs.generated))
                tr.async_end("request", rs.request.uid,
                             generated=len(rs.generated))
            if self.paged is not None:
                self._free_pages.extend(self._slot_pages[rs.slot])
                self._slot_pages[rs.slot] = []
                self._park_slot(rs.slot)

    def _park_slot(self, slot: int) -> None:
        """Point a freed slot's page-table rows back at the scratch page.
        Its pages return to the pool and may be reallocated immediately, but
        the empty slot keeps scattering pad-token K/V every decode tick (the
        compiled step has no notion of emptiness) — those writes must not
        land on pages a later owner holds."""
        scratch = jnp.full((self.paged.pages_per_slot_max,),
                           self.paged.pool_pages, jnp.int32)

        def one(dst):
            if isinstance(dst, PagedKVCache):
                return dst._replace(
                    page_table=dst.page_table.at[:, slot].set(scratch[None]))
            return dst

        self.state = self.state._replace(caches=jax.tree.map(
            one, self.state.caches,
            is_leaf=lambda x: isinstance(x, PagedKVCache)))

    def tick(self) -> int:
        """Admit + one decode step for all resident requests.
        Returns the number of live requests stepped."""
        self._admit()
        live = [s for s in self._slots if s is not None]
        if not live:
            return 0
        self.ticks += 1
        self._occupancy_sum += len(live)
        if self.paged is not None:
            self._pool_occ_sum += ((self.paged.pool_pages
                                    - len(self._free_pages))
                                   / self.paged.pool_pages)
            self._chunks_inflight_sum += (
                1.0 if self._prefilling is not None else 0.0)
        tr = obs_trace.active()
        if tr is not None:
            tr.counter("queue_depth", len(self.queue))
        t0 = time.perf_counter()
        with obs_trace.span("decode_tick", cat="serve", live=len(live),
                            tick=self.ticks):
            new_state, logits = self._decode(self.params, self.state,
                                             self._tokens[:, None])
            jax.block_until_ready(logits)
        self.decode_s += time.perf_counter() - t0
        self.state = new_state
        self._key, sub = jax.random.split(self._key)
        # per-slot temperatures: requests with different sampling settings
        # share one decode step, so each resident row decodes at its own
        # temperature (empty slots sample greedily into ignored outputs);
        # the all-greedy batch — the common replay case — keeps the scalar
        # argmax-only fast path
        if any(rs.request.temperature > 0.0 for rs in live):
            temps = np.zeros((self.num_slots,), np.float32)
            for rs in live:
                temps[rs.slot] = rs.request.temperature
            toks = sample_token(logits, sub, jnp.asarray(temps))
        else:
            toks = sample_token(logits, sub, 0.0)
        for rs in list(live):
            tok = int(toks[rs.slot])
            rs.generated.append(tok)
            self._tokens = self._tokens.at[rs.slot].set(tok)
            self._maybe_finish(rs, tok)
        return len(live)

    def run_until_drained(self, max_ticks: int = 10_000,
                          on_limit: str = "raise") -> List[RequestState]:
        """Tick until every submitted request finishes or ``max_ticks`` ticks
        (counted from this call) elapse.  Hitting the limit with work still
        pending is a stall, never silently partial results: ``on_limit`` is
        ``"raise"`` (:class:`DrainStall`, the default) or ``"warn"`` (emit a
        ``RuntimeWarning``, set :attr:`stalled`, return what completed)."""
        if on_limit not in ("raise", "warn"):
            raise ValueError(f"on_limit must be 'raise' or 'warn', "
                             f"got {on_limit!r}")
        self.stalled = False
        start = self.ticks
        while self.queue or self._prefilling is not None or \
                any(s is not None for s in self._slots):
            if self.ticks - start >= max_ticks:
                pending = (len(self.queue) + sum(
                    s is not None for s in self._slots)
                    + (self._prefilling is not None))
                msg = (f"batcher not drained after {max_ticks} ticks: "
                       f"{len(self.completed)} completed, {pending} pending")
                if on_limit == "raise":
                    raise DrainStall(msg, completed=len(self.completed),
                                     pending=pending)
                warnings.warn(msg, RuntimeWarning, stacklevel=2)
                self.stalled = True
                break
            if self.tick() == 0 and not self.queue and \
                    self._prefilling is None:
                break
        return self.completed

    # -- stats ----------------------------------------------------------------

    @property
    def mean_occupancy(self) -> float:
        return self._occupancy_sum / max(self.ticks, 1)
