"""Drive the real :class:`ContinuousBatcher` from a generated request trace.

This is the deployment end of the serving-workload loop: the simulator
(:mod:`repro.workloads.sim`) tunes the serving stack against a trace, and
this module replays the same trace through the actual jitted prefill/decode
steps under the tuned plan.  Trace arrival times (seconds of modeled time)
map onto batcher ticks through ``ticks_per_s``; by default the span of the
trace maps to roughly the number of decode ticks its tokens need, so the
offered load is preserved.

The admission chunk is honored here — at most ``admit_chunk`` requests are
released into the batcher's queue per tick — because the batcher itself
admits greedily into every free slot.

All statistics are **per replay**: counters snapshot the batcher's lifetime
state (``completed``, ticks, occupancy, prefill/decode wall time) at entry
and report only this replay's deltas, so a reused batcher (e.g. a
default-vs-tuned comparison on one deployment) never counts pre-replay
completions.
"""

from __future__ import annotations

from dataclasses import dataclass
from time import perf_counter
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import trace as obs_trace
from repro.serving.scheduler import ContinuousBatcher, DrainStall, Request
from repro.workloads.traces import Trace


@dataclass(frozen=True)
class ReplayReport:
    """Wall-clock statistics from one real-batcher trace replay.

    Every field covers only the replay that produced the report — a batcher
    that already served other traffic contributes nothing to these counts.
    """

    completed: int
    rejected: int                  # did not fit prompt+output in the cache
    ticks: int
    wall_s: float
    tokens: int
    mean_occupancy: float
    p50_latency_ms: float          # submit -> finish, wall clock
    p99_latency_ms: float
    queue_depth_mean: float = 0.0  # batcher queue depth sampled per tick
    queue_depth_max: float = 0.0
    prefill_s: float = 0.0         # wall time inside prefill launches
    decode_s: float = 0.0          # wall time inside decode launches
    latencies_ms: Tuple[float, ...] = ()  # per-request, completion order
    # paged-KV mediators, name-compatible with the simulator's; all zero for
    # dense deployments
    page_pool_occupancy: float = 0.0   # mean fraction of the pool in use
    page_faults: float = 0.0           # always 0: the real batcher defers
    prefill_chunks_inflight: float = 0.0
    rejected_too_long: int = 0     # batcher-side PromptTooLong rejections

    @property
    def prefill_decode_ratio(self) -> float:
        return self.prefill_s / max(self.decode_s, 1e-9)

    @property
    def throughput_rps(self) -> float:
        """Completed requests per wall-clock second of this replay."""
        return self.completed / max(self.wall_s, 1e-9)

    @property
    def rejected_rate(self) -> float:
        return self.rejected / max(self.rejected + self.completed, 1)

    def slo_violation_rate(self, slo_ms: float) -> float:
        if not self.latencies_ms:
            return 0.0
        return float(np.mean(np.asarray(self.latencies_ms) > slo_ms))

    def counters(self, slo_ms: float = float("inf")) -> Dict[str, float]:
        """The measurement's metrics dict, name-compatible with
        :meth:`repro.workloads.sim.SimReport.counters` so a simulator-trained
        causal model transfers onto replay measurements.  ``latency`` /
        ``throughput`` are objective clones for query constraints — like the
        simulator's they stay OUT of the discovery counter names."""
        return {
            "queue_depth_mean": self.queue_depth_mean,
            "queue_depth_max": self.queue_depth_max,
            "occupancy_mean": self.mean_occupancy,
            "prefill_decode_ratio": self.prefill_decode_ratio,
            "slo_violation_rate": self.slo_violation_rate(slo_ms),
            "page_pool_occupancy": self.page_pool_occupancy,
            "page_faults": self.page_faults,
            "prefill_chunks_inflight": self.prefill_chunks_inflight,
            "rejected_rate": self.rejected_rate,
            "rejected_too_long": float(self.rejected_too_long),
            "latency": self.p99_latency_ms,
            "throughput": self.throughput_rps,
        }


def default_ticks_per_s(trace: Trace, num_slots: int) -> float:
    """Map the trace span onto roughly the decode ticks its tokens need, so
    the replayed arrival process keeps the trace's load shape."""
    est_ticks = max(trace.total_output_tokens / max(num_slots, 1), 1.0)
    span = max(trace.span_s, 1e-9)
    return est_ticks / span


def trace_requests(trace: Trace, vocab_size: int, cache_len: int,
                   seed: Optional[int] = None) -> List[Request]:
    """Materialize the trace as batcher ``Request``s with seeded random
    token prompts.  Requests that cannot fit (prompt + output > cache_len)
    are dropped here — the simulator calls such a plan infeasible; the
    replay counts them as rejected."""
    rng = np.random.default_rng(trace.seed if seed is None else seed)
    out: List[Request] = []
    for r in trace.requests:
        if r.prompt_len + r.output_len > cache_len:
            continue
        prompt = rng.integers(0, vocab_size, size=r.prompt_len,
                              dtype=np.int32)
        out.append(Request(uid=r.uid, prompt=prompt,
                           max_new_tokens=r.output_len))
    return out


def replay_trace(batcher: ContinuousBatcher, trace: Trace, *,
                 admit_chunk: int = 4, ticks_per_s: Optional[float] = None,
                 seed: Optional[int] = None,
                 max_ticks: int = 100_000) -> ReplayReport:
    """Feed ``trace`` through ``batcher`` tick by tick and drain it.

    Deterministic given (batcher state, trace, seed): arrivals release in
    trace order at their mapped tick, at most ``admit_chunk`` per tick.
    Raises :class:`DrainStall` if the trace does not finish in ``max_ticks``;
    the stall's ``completed``/``pending`` count only this replay's requests.
    """
    if ticks_per_s is None:
        ticks_per_s = default_ticks_per_s(trace, batcher.num_slots)
    requests = trace_requests(trace, batcher.model.cfg.vocab_size,
                              batcher.cache_len, seed=seed)
    rejected = len(trace.requests) - len(requests)
    fitting = {r.uid for r in requests}
    arrival_tick = {r.uid: int(r.arrival_s * ticks_per_s)
                    for r in trace.requests if r.uid in fitting}

    # entry snapshots: everything reported below is a delta against these,
    # so a reused batcher's earlier traffic never leaks into this report
    start_completed = len(batcher.completed)
    start_ticks = batcher.ticks
    start_occupancy = batcher._occupancy_sum
    start_prefill_s = batcher.prefill_s
    start_decode_s = batcher.decode_s
    start_too_long = batcher.rejected_too_long
    start_pool_occ = batcher._pool_occ_sum
    start_chunks = batcher._chunks_inflight_sum

    t0 = perf_counter()
    submit_wall: Dict[int, float] = {}
    qd_sum, qd_max = 0.0, 0.0
    i, tick = 0, 0
    replay_span = obs_trace.span("replay", cat="replay",
                                 n_requests=len(requests), rejected=rejected,
                                 admit_chunk=admit_chunk)
    with replay_span:
        while i < len(requests) or batcher.queue or \
                batcher._prefilling is not None or any(
                s is not None for s in batcher._slots):
            released = 0
            while (i < len(requests) and released < admit_chunk
                   and arrival_tick[requests[i].uid] <= tick):
                submit_wall[requests[i].uid] = perf_counter()
                batcher.submit(requests[i])
                i += 1
                released += 1
            stepped = batcher.tick()
            tick += 1
            if stepped:
                qd_sum += len(batcher.queue)
                qd_max = max(qd_max, float(len(batcher.queue)))
            elif not batcher.queue and batcher._prefilling is None \
                    and i < len(requests):
                # idle: jump to the next arrival instead of spinning
                tick = max(tick, arrival_tick[requests[i].uid])
            if tick > max_ticks:
                done_here = len(batcher.completed) - start_completed
                pending = (len(requests) - i + len(batcher.queue)
                           + (batcher._prefilling is not None)
                           + sum(s is not None for s in batcher._slots))
                raise DrainStall(
                    f"trace replay not drained after {max_ticks} ticks "
                    f"({done_here} completed, {pending} pending)",
                    completed=done_here, pending=pending)
        replay_span.set(completed=len(batcher.completed) - start_completed,
                        ticks=batcher.ticks - start_ticks)

    done = batcher.completed[start_completed:]
    ticks_replay = batcher.ticks - start_ticks
    lat_ms = tuple(
        float((rs.finished_at - submit_wall[rs.request.uid]) * 1e3)
        for rs in done if rs.request.uid in submit_wall)
    lat = np.asarray(lat_ms)
    tokens = sum(len(rs.generated) for rs in done)
    too_long_here = batcher.rejected_too_long - start_too_long
    return ReplayReport(
        completed=len(done), rejected=rejected + too_long_here,
        ticks=ticks_replay, wall_s=perf_counter() - t0,
        tokens=tokens,
        mean_occupancy=((batcher._occupancy_sum - start_occupancy)
                        / max(ticks_replay, 1)),
        p50_latency_ms=float(np.percentile(lat, 50)) if len(lat) else 0.0,
        p99_latency_ms=float(np.percentile(lat, 99)) if len(lat) else 0.0,
        queue_depth_mean=qd_sum / max(ticks_replay, 1),
        queue_depth_max=qd_max,
        prefill_s=batcher.prefill_s - start_prefill_s,
        decode_s=batcher.decode_s - start_decode_s,
        latencies_ms=lat_ms,
        page_pool_occupancy=((batcher._pool_occ_sum - start_pool_occ)
                             / max(ticks_replay, 1)),
        prefill_chunks_inflight=((batcher._chunks_inflight_sum - start_chunks)
                                 / max(ticks_replay, 1)),
        rejected_too_long=too_long_here)
