"""Drive the real :class:`ContinuousBatcher` from a generated request trace.

This is the deployment end of the serving-workload loop: the simulator
(:mod:`repro.workloads.sim`) tunes the serving stack against a trace, and
this module replays the same trace through the actual jitted prefill/decode
steps under the tuned plan.  Trace arrival times (seconds of modeled time)
map onto batcher ticks through ``ticks_per_s``; by default the span of the
trace maps to roughly the number of decode ticks its tokens need, so the
offered load is preserved.

The admission chunk is honored here — at most ``admit_chunk`` requests are
released into the batcher's queue per tick — because the batcher itself
admits greedily into every free slot.
"""

from __future__ import annotations

from dataclasses import dataclass
from time import perf_counter
from typing import Dict, List, Optional

import numpy as np

from repro.serving.scheduler import ContinuousBatcher, DrainStall, Request
from repro.workloads.traces import Trace


@dataclass(frozen=True)
class ReplayReport:
    """Wall-clock statistics from one real-batcher trace replay."""

    completed: int
    rejected: int                  # did not fit prompt+output in the cache
    ticks: int
    wall_s: float
    tokens: int
    mean_occupancy: float
    p50_latency_ms: float          # submit -> finish, wall clock
    p99_latency_ms: float


def default_ticks_per_s(trace: Trace, num_slots: int) -> float:
    """Map the trace span onto roughly the decode ticks its tokens need, so
    the replayed arrival process keeps the trace's load shape."""
    est_ticks = max(trace.total_output_tokens / max(num_slots, 1), 1.0)
    span = max(trace.span_s, 1e-9)
    return est_ticks / span


def trace_requests(trace: Trace, vocab_size: int, cache_len: int,
                   seed: Optional[int] = None) -> List[Request]:
    """Materialize the trace as batcher ``Request``s with seeded random
    token prompts.  Requests that cannot fit (prompt + output > cache_len)
    are dropped here — the simulator calls such a plan infeasible; the
    replay counts them as rejected."""
    rng = np.random.default_rng(trace.seed if seed is None else seed)
    out: List[Request] = []
    for r in trace.requests:
        if r.prompt_len + r.output_len > cache_len:
            continue
        prompt = rng.integers(0, vocab_size, size=r.prompt_len,
                              dtype=np.int32)
        out.append(Request(uid=r.uid, prompt=prompt,
                           max_new_tokens=r.output_len))
    return out


def replay_trace(batcher: ContinuousBatcher, trace: Trace, *,
                 admit_chunk: int = 4, ticks_per_s: Optional[float] = None,
                 seed: Optional[int] = None,
                 max_ticks: int = 100_000) -> ReplayReport:
    """Feed ``trace`` through ``batcher`` tick by tick and drain it.

    Deterministic given (batcher state, trace, seed): arrivals release in
    trace order at their mapped tick, at most ``admit_chunk`` per tick.
    Raises :class:`DrainStall` if the trace does not finish in ``max_ticks``.
    """
    if ticks_per_s is None:
        ticks_per_s = default_ticks_per_s(trace, batcher.num_slots)
    requests = trace_requests(trace, batcher.model.cfg.vocab_size,
                              batcher.cache_len, seed=seed)
    rejected = len(trace.requests) - len(requests)
    fitting = {r.uid for r in requests}
    arrival_tick = {r.uid: int(r.arrival_s * ticks_per_s)
                    for r in trace.requests if r.uid in fitting}

    t0 = perf_counter()
    submit_wall: Dict[int, float] = {}
    i, tick, start_ticks = 0, 0, batcher.ticks
    while i < len(requests) or batcher.queue or any(
            s is not None for s in batcher._slots):
        released = 0
        while (i < len(requests) and released < admit_chunk
               and arrival_tick[requests[i].uid] <= tick):
            submit_wall[requests[i].uid] = perf_counter()
            batcher.submit(requests[i])
            i += 1
            released += 1
        stepped = batcher.tick()
        tick += 1
        if stepped == 0 and not batcher.queue and i < len(requests):
            # idle: jump to the next arrival instead of spinning
            tick = max(tick, arrival_tick[requests[i].uid])
        if tick > max_ticks:
            pending = (len(requests) - i + len(batcher.queue)
                       + sum(s is not None for s in batcher._slots))
            raise DrainStall(
                f"trace replay not drained after {max_ticks} ticks "
                f"({len(batcher.completed)} completed, {pending} pending)",
                completed=len(batcher.completed), pending=pending)

    lat_ms = np.asarray(
        [(rs.finished_at - submit_wall[rs.request.uid]) * 1e3
         for rs in batcher.completed if rs.request.uid in submit_wall])
    tokens = sum(len(rs.generated) for rs in batcher.completed)
    return ReplayReport(
        completed=len(batcher.completed), rejected=rejected,
        ticks=batcher.ticks - start_ticks, wall_s=perf_counter() - t0,
        tokens=tokens, mean_occupancy=batcher.mean_occupancy,
        p50_latency_ms=float(np.percentile(lat_ms, 50)) if len(lat_ms) else 0.0,
        p99_latency_ms=float(np.percentile(lat_ms, 99)) if len(lat_ms) else 0.0)
