from repro.serving.scheduler import (  # noqa: F401
    ContinuousBatcher, DrainStall, Request, RequestState)
from repro.serving.replay import (  # noqa: F401
    ReplayReport, default_ticks_per_s, replay_trace, trace_requests)
