from repro.serving.scheduler import (  # noqa: F401
    ContinuousBatcher, Request, RequestState)
