"""The paged-KV scheduler surface shared by the simulator, the batcher and
the serving environments.

Two kinds of knobs govern paging and they live in different registries:

- ``paged_attention.*`` — the kernel family's launch options (``page_size``,
  ``pages_per_slot_max``, ``prefill_chunk``), registered in
  :mod:`repro.kernels.dispatch` like every other launch knob and joining
  ``serving_space()`` through ``dispatch.launch_space()``.
- ``pages.*`` — scheduler options that are not kernel-launch parameters:
  whether paging is on at all and how large the shared pool is.  They deploy
  through :meth:`PagedPlan.from_config` exactly like ``serving.*`` deploys
  through ``ServingPlan.from_config`` (and are likewise excluded from
  ``launch_config_of``).

:class:`PagedPlan` is the resolved deployment: one immutable record both the
discrete-event simulator (:mod:`repro.workloads.sim`) and the real batcher
(:mod:`repro.serving.scheduler`) price/allocate with, so the sim-to-real
pair stays pinned to one paging geometry.

This module must stay import-light (no jax, no model stack): the simulator
and the scheduler both import it, and the scheduler cannot import the
simulator (the simulator already imports the scheduler's ``DrainStall``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

from repro.core.spaces import Option

PAGES_PREFIX = "pages."

# scheduler-level paging options (the kernel-level ones ride in the
# dispatch registry under the paged_attention family)
PAGES_OPTIONS: Tuple[Option, ...] = (
    Option("pages.paging", ("off", "on"), default="off", kind="categorical"),
    Option("pages.pool_pages", (64, 128, 256, 512), default=128),
)


@dataclass(frozen=True)
class PagedPlan:
    """One resolved paged-KV deployment.

    ``paging=False`` is the dense reference: the serving stack behaves
    exactly as before this plan existed.  With paging on, each admitted slot
    owns up to ``pages_per_slot_max`` pages of ``page_size`` tokens out of a
    shared ``pool_pages``-page pool; ``prefill_chunk`` > 0 splits prompt
    prefill into chunks admitted between decode ticks.
    """

    paging: bool = False
    pool_pages: int = 128
    page_size: int = 64
    pages_per_slot_max: int = 8
    prefill_chunk: int = 0

    @property
    def slot_capacity(self) -> int:
        """Max tokens one slot can ever hold (its page table filled)."""
        return self.page_size * self.pages_per_slot_max

    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` cache entries (at least one)."""
        return max(-(-int(tokens) // self.page_size), 1)

    @staticmethod
    def from_config(config: Dict[str, Any]) -> "PagedPlan":
        """Resolve a flat tuner config; missing keys fall back to the
        ``pages.*`` option defaults and the paged_attention registry
        defaults, so a config that never heard of paging resolves to the
        dense reference plan."""
        from repro.kernels import dispatch

        fam = dispatch.get_family("paged_attention")
        launch = {o.name: o.default for o in fam.launch_options}
        for o in fam.launch_options:
            key = f"paged_attention.{o.name}"
            if key in config:
                launch[o.name] = config[key]
        defaults = {o.name[len(PAGES_PREFIX):]: o.default
                    for o in PAGES_OPTIONS}
        paging = config.get("pages.paging", defaults["paging"])
        return PagedPlan(
            paging=(paging in (True, 1, "on")),
            pool_pages=int(config.get("pages.pool_pages",
                                      defaults["pool_pages"])),
            page_size=int(launch["page_size"]),
            pages_per_slot_max=int(launch["pages_per_slot_max"]),
            prefill_chunk=int(launch["prefill_chunk"]),
        )
