"""Summarize an exported trace file: ``python -m repro.obs.report trace.json``.

Prints, for a Chrome trace-event JSON written by :mod:`repro.obs.trace`:

- **top spans** — per span name: count, total / mean / max duration;
- **request lifecycle breakdown** — queue vs. prefill vs. decode time and
  per-request end-to-end latency from the async ``b``/``e`` request events;
- **SLO burn** — fraction of requests whose end-to-end latency exceeds
  ``--slo-ms`` (when request events are present);
- **tuner rounds** — per-round ask/tell events from the tuner track.

The same module exposes :func:`validate_trace_doc` — the schema check CI
and tier-1 tests run against every exported file.
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

# phases we emit (a subset of the Chrome trace-event vocabulary)
_KNOWN_PHASES = {"X", "i", "I", "C", "b", "e", "n", "B", "E", "M", "s", "t", "f"}
_LIFECYCLE_SPANS = ("queue", "prefill", "prefill_chunk", "decode_tick")


def validate_trace_doc(doc: Any) -> List[Dict[str, Any]]:
    """Validate a parsed trace document against the Chrome trace-event
    schema (JSON Object Format); return the event list.

    Raises ``ValueError`` on the first violation — used by tier-1 tests
    and by the report CLI before summarizing, so a malformed export fails
    loudly rather than rendering an empty report.
    """
    if isinstance(doc, list):          # JSON Array Format is also legal
        events = doc
    elif isinstance(doc, dict):
        if "traceEvents" not in doc:
            raise ValueError("trace document has no 'traceEvents' key")
        events = doc["traceEvents"]
    else:
        raise ValueError(f"trace document must be an object or array, "
                         f"got {type(doc).__name__}")
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object: {ev!r}")
        ph = ev.get("ph")
        if ph not in _KNOWN_PHASES:
            raise ValueError(f"event {i} has invalid phase {ph!r}")
        if ph != "M":
            if "ts" not in ev:
                raise ValueError(f"event {i} ({ev.get('name')!r}) missing 'ts'")
            if not isinstance(ev["ts"], (int, float)):
                raise ValueError(f"event {i} has non-numeric ts: {ev['ts']!r}")
        if not isinstance(ev.get("name", ""), str):
            raise ValueError(f"event {i} has non-string name")
        if "pid" in ev and not isinstance(ev["pid"], int):
            raise ValueError(f"event {i} has non-integer pid: {ev['pid']!r}")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(
                    f"event {i} ({ev.get('name')!r}) 'X' span needs dur >= 0")
        if ph in ("b", "e", "n") and "id" not in ev:
            raise ValueError(f"event {i} async phase {ph!r} missing 'id'")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"event {i} has non-object args")
    return events


def load_trace(path: str) -> List[Dict[str, Any]]:
    with open(path) as f:
        doc = json.load(f)
    return validate_trace_doc(doc)


# -- aggregation ------------------------------------------------------------

def span_stats(events: Iterable[Mapping[str, Any]]) -> Dict[str, Dict[str, float]]:
    """Per-name duration stats over all complete ('X') spans."""
    stats: Dict[str, Dict[str, float]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        s = stats.setdefault(ev.get("name", "?"),
                             {"count": 0, "total_us": 0.0, "max_us": 0.0})
        d = float(ev.get("dur", 0.0))
        s["count"] += 1
        s["total_us"] += d
        s["max_us"] = max(s["max_us"], d)
    for s in stats.values():
        s["mean_us"] = s["total_us"] / s["count"] if s["count"] else 0.0
    return stats


def request_latencies(events: Iterable[Mapping[str, Any]]) -> Dict[str, float]:
    """End-to-end latency (us) per request id from async b/e pairs."""
    begin: Dict[Tuple[str, str], float] = {}
    out: Dict[str, float] = {}
    for ev in events:
        ph = ev.get("ph")
        if ph == "b":
            begin[(ev.get("name", ""), str(ev.get("id")))] = float(ev["ts"])
        elif ph == "e":
            key = (ev.get("name", ""), str(ev.get("id")))
            t0 = begin.pop(key, None)
            if t0 is not None:
                out[key[1]] = float(ev["ts"]) - t0
    return out


def lifecycle_breakdown(events: Iterable[Mapping[str, Any]]) -> Dict[str, float]:
    """Total time (us) in each request-lifecycle stage across the trace."""
    stats = span_stats(events)
    return {name: stats[name]["total_us"]
            for name in _LIFECYCLE_SPANS if name in stats}


def slo_burn(latencies: Mapping[str, float], slo_ms: float) -> Dict[str, float]:
    n = len(latencies)
    viol = sum(1 for v in latencies.values() if v > slo_ms * 1e3)
    return {"requests": float(n), "slo_ms": slo_ms,
            "violations": float(viol),
            "burn_rate": viol / n if n else 0.0}


def tuner_round_summary(events: Iterable[Mapping[str, Any]]) -> List[Dict[str, Any]]:
    return [{"name": ev.get("name"), "ts": ev.get("ts"),
             "args": ev.get("args", {})}
            for ev in events if ev.get("cat") == "tuner"]


def summarize(events: List[Dict[str, Any]], slo_ms: float = 50.0,
              top: int = 12) -> Dict[str, Any]:
    """The full report as a JSON-able dict (the CLI pretty-prints this)."""
    stats = span_stats(events)
    lats = request_latencies(events)
    return {
        "num_events": len(events),
        "top_spans": sorted(
            ({"name": k, **v} for k, v in stats.items()),
            key=lambda s: -s["total_us"])[:top],
        "lifecycle_us": lifecycle_breakdown(events),
        "slo": slo_burn(lats, slo_ms),
        "tuner_rounds": tuner_round_summary(events),
    }


# -- CLI --------------------------------------------------------------------

def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:8.3f}s "
    if us >= 1e3:
        return f"{us / 1e3:8.3f}ms"
    return f"{us:8.1f}us"


def render(report: Mapping[str, Any], out=sys.stdout) -> None:
    w = out.write
    w(f"trace: {report['num_events']} events\n\n")

    w("top spans (by total duration)\n")
    w(f"  {'name':<28}{'count':>7}{'total':>11}{'mean':>11}{'max':>11}\n")
    for s in report["top_spans"]:
        w(f"  {s['name']:<28}{s['count']:>7.0f}{_fmt_us(s['total_us']):>11}"
          f"{_fmt_us(s['mean_us']):>11}{_fmt_us(s['max_us']):>11}\n")

    life = report["lifecycle_us"]
    if life:
        total = sum(life.values()) or 1.0
        w("\nrequest lifecycle breakdown\n")
        for name, us in life.items():
            w(f"  {name:<16}{_fmt_us(us):>11}  {100.0 * us / total:5.1f}%\n")

    slo = report["slo"]
    if slo["requests"]:
        w(f"\nSLO burn @ {slo['slo_ms']:g} ms: "
          f"{slo['violations']:.0f}/{slo['requests']:.0f} requests over "
          f"({100.0 * slo['burn_rate']:.1f}%)\n")

    rounds = report["tuner_rounds"]
    if rounds:
        w(f"\ntuner rounds ({len(rounds)} events)\n")
        for ev in rounds:
            args = ev.get("args", {})
            keys = ("tuner", "round", "k", "told", "best_y", "eps",
                    "graph_refreshed", "n_reduced")
            brief = ", ".join(f"{k}={args[k]}" for k in keys if k in args)
            w(f"  {ev['name']:<16}{brief}\n")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a Chrome trace-event JSON exported by repro.obs")
    ap.add_argument("trace", help="path to the trace JSON file")
    ap.add_argument("--slo-ms", type=float, default=50.0,
                    help="per-request latency SLO for the burn-rate section")
    ap.add_argument("--top", type=int, default=12,
                    help="how many span names to list")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of text")
    args = ap.parse_args(argv)

    try:
        events = load_trace(args.trace)
    except (OSError, json.JSONDecodeError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    report = summarize(events, slo_ms=args.slo_ms, top=args.top)
    if args.json:
        json.dump(report, sys.stdout, indent=2)
        sys.stdout.write("\n")
    else:
        render(report)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
