"""Span tracer: monotonic nested spans, zero-cost when disabled, exported as
Chrome trace-event / Perfetto JSON.

Design constraints, in order:

1. **Zero cost disabled.**  No tracer is installed by default; every
   instrumentation site guards on :func:`enabled` (one global read) or calls
   a module helper that returns a shared no-op span.  Instrumented code
   paths draw no RNG, allocate nothing, and take no locks when tracing is
   off — the serving counters, replayed tokens, and tuned trajectories are
   bit-identical with and without the tracer compiled in.
2. **One event vocabulary.**  Everything exports to the Chrome trace-event
   format (the ``{"traceEvents": [...]}`` JSON object Perfetto and
   ``chrome://tracing`` load): complete spans (``ph: "X"``), instants
   (``"i"``), counters (``"C"``), async request lifecycles (``"b"``/``"e"``
   keyed by request uid), and process/thread-name metadata (``"M"``).
3. **Two clocks.**  Wall spans (the real batcher, env measurements, kernel
   dispatch) timestamp from a monotonic epoch captured at tracer start; the
   discrete-event simulator emits spans at *modeled* microseconds on its own
   process track (:data:`TRACK_SIM`), so one trace file holds both the real
   and the modeled view of a serving run.

Tracks are logical Chrome "processes" (integer pids with name metadata):
serving wall time, simulator modeled time, tuner rounds, kernel dispatch,
and environment measurements.
"""

from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional, Tuple

#: logical process ids of the exported trace (named via "M" metadata events)
TRACK_SERVE = 1     # real batcher / replay wall time
TRACK_SIM = 2       # discrete-event simulator, modeled microseconds
TRACK_TUNER = 3     # per-round tuner events
TRACK_KERNEL = 4    # kernel dispatch resolutions / jit cache
TRACK_ENV = 5       # environment measurements (deploy / warmup / replay)

TRACK_NAMES = {
    TRACK_SERVE: "serving (wall)",
    TRACK_SIM: "simulator (modeled us)",
    TRACK_TUNER: "tuner rounds",
    TRACK_KERNEL: "kernel dispatch",
    TRACK_ENV: "env measurements",
}


class _NullSpan:
    """The shared disabled-path span: every method is a no-op."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def set(self, **args: Any) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class _Span:
    """One live complete-event span; records duration on ``__exit__``."""

    __slots__ = ("_tracer", "name", "cat", "track", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, track: int,
                 args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.track = track
        self.args = args
        self._t0 = 0.0

    def set(self, **args: Any) -> "_Span":
        """Attach (or overwrite) args on the open span."""
        self.args.update(args)
        return self

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer.now_us()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        self._tracer.complete(self.name, self._t0,
                              self._tracer.now_us() - self._t0,
                              cat=self.cat, track=self.track, **self.args)
        return False


class Tracer:
    """Collects trace events; thread-safe; bounded.

    ``max_events`` caps memory for long traced sweeps — once full, further
    events are counted (``dropped``) instead of stored, and the export
    records the drop count in ``otherData`` so a truncated trace is never
    mistaken for a complete one.
    """

    def __init__(self, clock=time.perf_counter, max_events: int = 1_000_000):
        self._clock = clock
        self._epoch = clock()
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self.max_events = int(max_events)
        self.dropped = 0
        #: structured per-round tuner introspection records, in emission
        #: order — the programmatic dual of the exported tuner track
        self.tuner_rounds: List[Dict[str, Any]] = []

    # -- clocks ---------------------------------------------------------

    def now_us(self) -> float:
        """Microseconds since tracer start (monotonic)."""
        return (self._clock() - self._epoch) * 1e6

    # -- event sinks ----------------------------------------------------

    def _push(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append(ev)

    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 cat: str = "span", track: int = TRACK_SERVE,
                 tid: int = 0, **args: Any) -> None:
        """A finished span at an explicit timestamp (``ph: "X"``) — the
        entry point for modeled-time spans, whose clock is the simulator's."""
        self._push({"name": name, "cat": cat, "ph": "X",
                    "ts": round(ts_us, 3), "dur": round(max(dur_us, 0.0), 3),
                    "pid": track, "tid": tid, "args": args})

    def span(self, name: str, *, cat: str = "span",
             track: int = TRACK_SERVE, **args: Any) -> _Span:
        """A context-managed wall-clock span."""
        return _Span(self, name, cat, track, dict(args))

    def instant(self, name: str, *, cat: str = "event",
                track: int = TRACK_SERVE, tid: int = 0,
                ts_us: Optional[float] = None, **args: Any) -> None:
        self._push({"name": name, "cat": cat, "ph": "i", "s": "t",
                    "ts": round(self.now_us() if ts_us is None else ts_us, 3),
                    "pid": track, "tid": tid, "args": args})

    def counter(self, name: str, value: float, *,
                track: int = TRACK_SERVE, tid: int = 0,
                ts_us: Optional[float] = None, series: str = "value") -> None:
        self._push({"name": name, "cat": "counter", "ph": "C",
                    "ts": round(self.now_us() if ts_us is None else ts_us, 3),
                    "pid": track, "tid": tid, "args": {series: float(value)}})

    def async_begin(self, name: str, uid: Any, *, cat: str = "request",
                    track: int = TRACK_SERVE,
                    ts_us: Optional[float] = None, **args: Any) -> None:
        self._push({"name": name, "cat": cat, "ph": "b", "id": str(uid),
                    "ts": round(self.now_us() if ts_us is None else ts_us, 3),
                    "pid": track, "tid": 0, "args": args})

    def async_end(self, name: str, uid: Any, *, cat: str = "request",
                  track: int = TRACK_SERVE,
                  ts_us: Optional[float] = None, **args: Any) -> None:
        self._push({"name": name, "cat": cat, "ph": "e", "id": str(uid),
                    "ts": round(self.now_us() if ts_us is None else ts_us, 3),
                    "pid": track, "tid": 0, "args": args})

    def tuner_event(self, kind: str, **payload: Any) -> None:
        """One structured tuner event: kept as a Python record on
        :attr:`tuner_rounds` AND exported as an instant on the tuner track,
        so the trajectory is inspectable both programmatically and in the
        trace viewer."""
        rec = {"kind": kind, **payload}
        with self._lock:
            self.tuner_rounds.append(rec)
        self.instant(kind, cat="tuner", track=TRACK_TUNER, **_jsonable(payload))

    # -- export ---------------------------------------------------------

    def to_json(self) -> Dict[str, Any]:
        """The Chrome trace-event document (JSON Object Format)."""
        meta = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                 "args": {"name": label}}
                for pid, label in TRACK_NAMES.items()]
        with self._lock:
            events = list(self._events)
            dropped = self.dropped
        return {
            "traceEvents": meta + [_jsonable_event(e) for e in events],
            "displayTimeUnit": "ms",
            "otherData": {"exporter": "repro.obs", "dropped": dropped,
                          "num_events": len(events)},
        }

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return path

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)


def _jsonable(obj: Any) -> Any:
    """Coerce numpy scalars / tuples / nested dicts to plain JSON types."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "item"):           # numpy scalar
        return obj.item()
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def _jsonable_event(ev: Dict[str, Any]) -> Dict[str, Any]:
    if "args" in ev:
        ev = dict(ev)
        ev["args"] = _jsonable(ev["args"])
    return ev


# --------------------------------------------------------------------------
# the global tracer — one per process, None (disabled) by default
# --------------------------------------------------------------------------

_ACTIVE: Optional[Tracer] = None


def active() -> Optional[Tracer]:
    return _ACTIVE


def enabled() -> bool:
    """The guard every instrumentation site checks first — one global read,
    so the disabled path costs a single attribute load."""
    return _ACTIVE is not None


def start(clock=time.perf_counter, max_events: int = 1_000_000) -> Tracer:
    """Install a fresh global tracer (replacing any active one)."""
    global _ACTIVE
    _ACTIVE = Tracer(clock=clock, max_events=max_events)
    return _ACTIVE


def stop() -> Optional[Tracer]:
    """Uninstall and return the active tracer (None if none was active)."""
    global _ACTIVE
    t, _ACTIVE = _ACTIVE, None
    return t


@contextmanager
def trace_to(path: Optional[str] = None,
             max_events: int = 1_000_000) -> Iterator[Tracer]:
    """Trace everything underneath; export to ``path`` on exit (even when
    the body raises — a partial trace of a failed run is exactly when you
    want one).  Restores the previously-active tracer afterwards."""
    global _ACTIVE
    prev = _ACTIVE
    tracer = start(max_events=max_events)
    try:
        yield tracer
    finally:
        _ACTIVE = prev
        if path:
            tracer.export(path)


# -- module-level helpers: no-ops when disabled -----------------------------

def span(name: str, *, cat: str = "span", track: int = TRACK_SERVE,
         **args: Any):
    t = _ACTIVE
    if t is None:
        return NULL_SPAN
    return t.span(name, cat=cat, track=track, **args)


def instant(name: str, **kw: Any) -> None:
    t = _ACTIVE
    if t is not None:
        t.instant(name, **kw)


def counter(name: str, value: float, **kw: Any) -> None:
    t = _ACTIVE
    if t is not None:
        t.counter(name, value, **kw)


def tuner_event(kind: str, **payload: Any) -> None:
    t = _ACTIVE
    if t is not None:
        t.tuner_event(kind, **payload)
