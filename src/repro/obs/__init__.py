"""Observability subsystem: span tracing, the unified metrics registry,
and trace reporting.

- :mod:`repro.obs.trace` — nested span tracer with Chrome trace-event /
  Perfetto JSON export; zero-cost (and bit-identical) when disabled.
- :mod:`repro.obs.metrics` — the metrics registry that is the single
  source of truth for discovery-variable names, plus labeled runtime
  instruments.
- :mod:`repro.obs.report` — ``python -m repro.obs.report trace.json``
  summarizes an exported trace (top spans, queue-time breakdown, SLO
  burn, tuner rounds) and validates it against the trace-event schema.
"""

from repro.obs import trace
from repro.obs.metrics import REGISTRY, MetricSpec, MetricsRegistry, declare, discovery_names
from repro.obs.trace import (
    NULL_SPAN,
    TRACK_ENV,
    TRACK_KERNEL,
    TRACK_SERVE,
    TRACK_SIM,
    TRACK_TUNER,
    Tracer,
    active,
    enabled,
    span,
    start,
    stop,
    trace_to,
)

__all__ = [
    "trace",
    "REGISTRY",
    "MetricSpec",
    "MetricsRegistry",
    "declare",
    "discovery_names",
    "NULL_SPAN",
    "TRACK_ENV",
    "TRACK_KERNEL",
    "TRACK_SERVE",
    "TRACK_SIM",
    "TRACK_TUNER",
    "Tracer",
    "active",
    "enabled",
    "span",
    "start",
    "stop",
    "trace_to",
]
