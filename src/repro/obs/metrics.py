"""Unified metrics registry: the single source of truth for discovery
variables, plus labeled counter / gauge / histogram instruments.

CAMEO's causal discovery runs over *named mediating variables* — the
serving counters sampled by the simulator, the fleet, and the real-batcher
replay.  Before this module those names lived in hand-maintained tuples
(``SIM_COUNTER_NAMES`` et al.) that sim and replay had to keep in sync by
convention.  Now each subsystem **declares** its metrics here once, in a
named group, and the legacy tuples are *derived*:

    ``SIM_COUNTER_NAMES``          = ``discovery_names("serving")``
    ``FLEET_COUNTER_NAMES``        = serving + fleet
    ``REPLAY_COUNTER_NAMES``       = serving + replay
    ``REPLAY_FLEET_COUNTER_NAMES`` = serving + replay + fleet

Group concatenation (not global registration order) defines each composite
tuple, so the derived orders are exactly the historical ones — column order
feeds the discovery matrix, so it is part of the numerical contract.

New subsystems register a new group (``declare(..., group="mygroup")``)
and compose it into their environment's counter names instead of appending
to a tuple in someone else's module.

The registry also carries *live* instruments (labeled counters, gauges,
histograms) used by the runtime telemetry (kernel dispatch profiling, jit
cache hit/miss accounting, ``MetricsLogger`` routing).  Instruments are
process-global, thread-safe, and cheap; they are bookkeeping only and never
feed back into scheduling or tuning decisions.
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

KINDS = ("counter", "gauge", "histogram")


@dataclass(frozen=True)
class MetricSpec:
    """One declared metric.

    ``discovery=True`` marks a *mediating variable*: it joins the derived
    discovery-name tuple of its group.  ``discovery=False`` declares a
    bookkeeping metric (objective clones like ``latency``/``throughput``,
    runtime telemetry) that reports may include but the causal graph must
    never treat as a mediator.
    """

    name: str
    kind: str = "gauge"
    help: str = ""
    group: str = "default"
    discovery: bool = True
    unit: str = ""

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"metric kind must be one of {KINDS}: {self.kind!r}")


def _labels_key(labels: Mapping[str, Any]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


@dataclass
class _Histogram:
    count: int = 0
    total: float = 0.0
    vmin: float = math.inf
    vmax: float = -math.inf

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def summary(self) -> Dict[str, float]:
        mean = self.total / self.count if self.count else 0.0
        return {"count": float(self.count), "sum": self.total,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0, "mean": mean}


class MetricsRegistry:
    """Declarations (ordered, per group) + live instrument values."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._specs: Dict[str, MetricSpec] = {}
        self._order: List[str] = []
        self._counters: Dict[Tuple[str, Tuple], float] = {}
        self._gauges: Dict[Tuple[str, Tuple], float] = {}
        self._hists: Dict[Tuple[str, Tuple], _Histogram] = {}

    # -- declarations ---------------------------------------------------

    def declare(self, name: str, *, kind: str = "gauge", help: str = "",
                group: str = "default", discovery: bool = True,
                unit: str = "") -> MetricSpec:
        """Register a metric.  Re-declaring with an identical spec is a
        no-op (modules re-import under pytest); a conflicting re-declare
        raises — silent drift between two declarations of one name is the
        exact failure mode this registry exists to prevent."""
        spec = MetricSpec(name=name, kind=kind, help=help, group=group,
                          discovery=discovery, unit=unit)
        with self._lock:
            prev = self._specs.get(name)
            if prev is not None:
                if prev != spec:
                    raise ValueError(
                        f"metric {name!r} already declared as {prev}, "
                        f"conflicting re-declaration {spec}")
                return prev
            self._specs[name] = spec
            self._order.append(name)
            return spec

    def spec(self, name: str) -> MetricSpec:
        with self._lock:
            return self._specs[name]

    def names(self, group: Optional[str] = None) -> Tuple[str, ...]:
        """All declared names, in declaration order (optionally one group)."""
        with self._lock:
            return tuple(n for n in self._order
                         if group is None or self._specs[n].group == group)

    def discovery_names(self, *groups: str) -> Tuple[str, ...]:
        """The discovery-variable tuple: for each group in the order given,
        its ``discovery=True`` metrics in declaration order.  Composite
        surfaces (fleet replay, …) are concatenations of groups — group
        order is the caller's contract, column order is the matrix
        contract."""
        out: List[str] = []
        with self._lock:
            for g in groups:
                out.extend(n for n in self._order
                           if self._specs[n].group == g
                           and self._specs[n].discovery)
        return tuple(out)

    def groups(self) -> Tuple[str, ...]:
        with self._lock:
            seen: List[str] = []
            for n in self._order:
                g = self._specs[n].group
                if g not in seen:
                    seen.append(g)
            return tuple(seen)

    # -- live instruments ----------------------------------------------

    def _known(self, name: str, kind: str) -> None:
        spec = self._specs.get(name)
        if spec is None:
            # auto-declare bookkeeping metrics on first touch; discovery
            # variables must be declared explicitly up front
            self.declare(name, kind=kind, group="runtime", discovery=False)
        elif spec.kind != kind:
            raise ValueError(f"metric {name!r} is a {spec.kind}, not a {kind}")

    def inc(self, name: str, value: float = 1.0, **labels: Any) -> float:
        self._known(name, "counter")
        key = (name, _labels_key(labels))
        with self._lock:
            cur = self._counters.get(key, 0.0) + float(value)
            self._counters[key] = cur
            return cur

    def set(self, name: str, value: float, **labels: Any) -> None:
        self._known(name, "gauge")
        key = (name, _labels_key(labels))
        with self._lock:
            self._gauges[key] = float(value)

    def observe(self, name: str, value: float, **labels: Any) -> None:
        self._known(name, "histogram")
        key = (name, _labels_key(labels))
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = _Histogram()
            h.observe(float(value))

    def value(self, name: str, **labels: Any) -> Optional[float]:
        key = (name, _labels_key(labels))
        with self._lock:
            if key in self._counters:
                return self._counters[key]
            if key in self._gauges:
                return self._gauges[key]
            return None

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """All live instrument values: ``{name: {label_repr: value}}``."""
        def fmt(key: Tuple) -> str:
            return ",".join(f"{k}={v}" for k, v in key) or ""

        out: Dict[str, Dict[str, Any]] = {}
        with self._lock:
            for (name, lk), v in self._counters.items():
                out.setdefault(name, {})[fmt(lk)] = v
            for (name, lk), v in self._gauges.items():
                out.setdefault(name, {})[fmt(lk)] = v
            for (name, lk), h in self._hists.items():
                out.setdefault(name, {})[fmt(lk)] = h.summary()
        return out

    def reset_values(self) -> None:
        """Clear live instrument values (declarations persist)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


#: the process-global registry every subsystem declares into
REGISTRY = MetricsRegistry()


def declare(name: str, **kw: Any) -> MetricSpec:
    return REGISTRY.declare(name, **kw)


def discovery_names(*groups: str) -> Tuple[str, ...]:
    return REGISTRY.discovery_names(*groups)
