"""CLI for the static-analysis subsystem.

Usage:
    PYTHONPATH=src python -m repro.analysis [paths...]        # report
    PYTHONPATH=src python -m repro.analysis --gate src/       # CI gate
    PYTHONPATH=src python -m repro.analysis --format github --gate src/
    PYTHONPATH=src python -m repro.analysis --write-baseline src/
    PYTHONPATH=src python -m repro.analysis --list-rules

Exit code: 0 clean (or gating disabled), 1 when ``--gate`` and any
error-severity finding (including stale baseline entries) survives.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.analysis import engine


def format_text(rep: engine.Report) -> str:
    lines: List[str] = []
    for f in rep.findings:
        lines.append(f"{f.path}:{f.line}: [{f.rule}] {f.message}")
    lines.append(
        f"{len(rep.findings)} finding(s) ({len(rep.errors)} errors, "
        f"{len(rep.warnings)} warnings), {len(rep.suppressed)} suppressed "
        f"inline, {len(rep.grandfathered)} grandfathered; "
        f"{rep.files_scanned} files scanned, {rep.configs_checked} launch "
        f"configs VMEM-checked")
    return "\n".join(lines)


def format_github(rep: engine.Report) -> str:
    lines: List[str] = []
    for f in rep.findings:
        kind = "error" if f.severity == engine.ERROR else "warning"
        # GitHub annotation command escaping for the message payload
        msg = f.message.replace("%", "%25").replace("\r", "%0D") \
                       .replace("\n", "%0A")
        lines.append(f"::{kind} file={f.path},line={f.line},"
                     f"title={f.rule}::{msg}")
    lines.append(f"::notice::repro.analysis: {len(rep.errors)} errors, "
                 f"{len(rep.warnings)} warnings over {rep.files_scanned} "
                 f"files; {rep.configs_checked} launch configs VMEM-checked")
    return "\n".join(lines)


def format_json(rep: engine.Report) -> str:
    return json.dumps({
        "version": engine.BASELINE_VERSION,
        "findings": [f.to_dict() for f in rep.findings],
        "suppressed": [{**f.to_dict(), "reason": reason}
                       for f, reason in rep.suppressed],
        "grandfathered": [f.to_dict() for f in rep.grandfathered],
        "summary": {
            "errors": len(rep.errors),
            "warnings": len(rep.warnings),
            "files_scanned": rep.files_scanned,
            "configs_checked": rep.configs_checked,
            "gate_ok": rep.gate_ok,
        },
    }, indent=1, sort_keys=True)


FORMATTERS = {"text": format_text, "github": format_github,
              "json": format_json}


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="contract linter + pallas kernel safety checker")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to analyze (default: src)")
    ap.add_argument("--format", choices=sorted(FORMATTERS), default="text")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 when any error-severity finding survives")
    ap.add_argument("--baseline", default=engine.DEFAULT_BASELINE,
                    help="grandfathered-findings file (missing = empty)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current unsuppressed findings to the "
                         "baseline file and exit 0")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the AST contract lint layer")
    ap.add_argument("--no-kernels", action="store_true",
                    help="skip the pallas kernel safety layer")
    ap.add_argument("--no-audits", action="store_true",
                    help="skip the registry audit layer")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in sorted(engine.RULES):
            severity, desc = engine.RULES[rule]
            print(f"{rule:28s} [{severity}] {desc}")
        return 0

    if args.write_baseline:
        rep = engine.run_analysis(
            args.paths, lint=not args.no_lint, kernels=not args.no_kernels,
            audits=not args.no_audits, baseline_path=None)
        engine.write_baseline(rep.findings, args.baseline)
        print(f"wrote {len(rep.findings)} finding(s) to {args.baseline}")
        return 0

    rep = engine.run_analysis(
        args.paths, lint=not args.no_lint, kernels=not args.no_kernels,
        audits=not args.no_audits, baseline_path=args.baseline)
    print(FORMATTERS[args.format](rep))
    if args.gate and not rep.gate_ok:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
