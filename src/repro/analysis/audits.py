"""Registry audits: the scattered pinning-test invariants as one pass.

Everything here inspects live registries (imports the real modules), so the
audit catches exactly what a runtime user would hit:

- ``audit-family-registration`` — every ``kernels/<family>/`` directory with
  a ``kernel.py`` registers in ``dispatch.py`` and exposes launch
  ``Option``s (the ROADMAP contract: new kernel knobs join the tunable
  surface).
- ``audit-option-space`` — ``launch_space()`` joined with the full
  ``serving_space(fleet=True)`` (paged knobs ride in when
  ``paged_attention`` is registered) builds without duplicate names; every
  Option name is well-formed and its default lies in its domain.
- ``audit-counters`` — every counter the sim / fleet / replay reports emit
  is declared in :mod:`repro.obs.metrics`, and every discovery name the
  causal layer consumes is actually emitted (column drift in either
  direction breaks discovery-matrix transfer).
- ``audit-registry-names`` — ``SHIFT_KINDS`` / workload kinds / measurement
  backend names are well-formed and collision-free.
"""

from __future__ import annotations

import dataclasses
import os
import re
import typing
from typing import Any, Dict, List, Set, Tuple

from repro.analysis.engine import Finding, norm_path

NAME_RE = re.compile(r"^[a-z][a-z0-9_]*$")
OPTION_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*(\.[a-z][a-z0-9_]*)?$")


def _anchor(module) -> Tuple[str, int]:
    return norm_path(getattr(module, "__file__", "<module>")), 1


def _zero_value(tp: Any) -> Any:
    origin = typing.get_origin(tp)
    if origin is not None:
        return ()
    return {int: 0, float: 0.0, str: "", bool: True}.get(tp, None)


def _zero_report(cls):
    """A dataclass report instance with every field zeroed (defaults kept),
    so ``.counters()`` can be keyed without running a workload."""
    kw: Dict[str, Any] = {}
    hints = typing.get_type_hints(cls)
    for f in dataclasses.fields(cls):
        if f.default is not dataclasses.MISSING:
            continue
        if f.default_factory is not dataclasses.MISSING:  # type: ignore[misc]
            continue
        kw[f.name] = _zero_value(hints.get(f.name, float))
    return cls(**kw)


# --------------------------------------------------------------------------
# family registration
# --------------------------------------------------------------------------

def audit_family_registration() -> List[Finding]:
    from repro.kernels import dispatch
    findings: List[Finding] = []
    kernels_dir = os.path.dirname(dispatch.__file__)
    registered = set(dispatch.families())
    for entry in sorted(os.listdir(kernels_dir)):
        kernel_py = os.path.join(kernels_dir, entry, "kernel.py")
        if not os.path.isfile(kernel_py):
            continue
        path = norm_path(kernel_py)
        if entry not in registered:
            findings.append(Finding(
                path, 1, "audit-family-registration",
                f"kernels/{entry}/ has a kernel.py but no "
                f"register_family(name={entry!r}) in dispatch.py"))
            continue
        if not dispatch.get_family(entry).launch_options:
            findings.append(Finding(
                path, 1, "audit-family-registration",
                f"family {entry!r} registers no launch Options — its knobs "
                f"never join launch_space()"))
    return findings


# --------------------------------------------------------------------------
# option spaces
# --------------------------------------------------------------------------

def _audit_space(space, label: str, module) -> List[Finding]:
    findings: List[Finding] = []
    path, line = _anchor(module)
    seen: Set[str] = set()
    for o in space.options:
        if o.name in seen:
            findings.append(Finding(
                path, line, "audit-option-space",
                f"{label}: duplicate Option name {o.name!r}"))
        seen.add(o.name)
        if not OPTION_NAME_RE.match(o.name):
            findings.append(Finding(
                path, line, "audit-option-space",
                f"{label}: ill-formed Option name {o.name!r}"))
        if not o.values:
            findings.append(Finding(
                path, line, "audit-option-space",
                f"{label}: Option {o.name!r} has an empty domain"))
        elif o.default not in o.values:
            findings.append(Finding(
                path, line, "audit-option-space",
                f"{label}: Option {o.name!r} default {o.default!r} outside "
                f"its domain {list(o.values)!r}"))
    return findings


def audit_option_spaces() -> List[Finding]:
    from repro.kernels import dispatch
    from repro.workloads import sim
    findings: List[Finding] = []
    findings += _audit_space(dispatch.launch_space(), "launch_space()",
                             dispatch)
    try:
        # full serving surface: scheduler + fleet + pages (paged_attention
        # is registered) + every launch option
        space = sim.serving_space(fleet=True)
    except ValueError as e:
        path, line = _anchor(sim)
        return findings + [Finding(
            path, line, "audit-option-space",
            f"serving_space(fleet=True) failed to build: {e}")]
    findings += _audit_space(space, "serving_space(fleet=True)", sim)
    return findings


# --------------------------------------------------------------------------
# counters vs declarations
# --------------------------------------------------------------------------

def audit_counters() -> List[Finding]:
    from repro.envs import replay_env
    from repro.obs import metrics as obs_metrics
    from repro.serving import replay as serving_replay
    from repro.workloads import sim
    findings: List[Finding] = []
    declared = set(obs_metrics.REGISTRY.names())

    sim_keys = set(_zero_report(sim.SimReport).counters())
    fleet_keys = set(_zero_report(sim.FleetReport).counters())
    replay_keys = set(_zero_report(serving_replay.ReplayReport).counters())

    surfaces = [
        (sim, "SimReport.counters()", sim_keys,
         set(sim.SIM_COUNTER_NAMES)),
        (sim, "FleetReport.counters()", fleet_keys,
         set(sim.FLEET_COUNTER_NAMES)),
        (serving_replay, "ReplayReport.counters()", replay_keys,
         set(replay_env.REPLAY_COUNTER_NAMES)),
    ]
    for module, label, emitted, discovery in surfaces:
        path, line = _anchor(module)
        undeclared = sorted(emitted - declared)
        if undeclared:
            findings.append(Finding(
                path, line, "audit-counters",
                f"{label} emits {undeclared} without a repro.obs.metrics "
                f"declaration"))
        missing = sorted(discovery - emitted)
        if missing:
            findings.append(Finding(
                path, line, "audit-counters",
                f"{label} never emits declared discovery counter(s) "
                f"{missing} — the discovery matrix would carry dead "
                f"columns"))
    # the replay-fleet tuple composes replay + fleet groups; every name must
    # come from one of the two emitting surfaces
    path, line = _anchor(replay_env)
    extra = sorted(set(replay_env.REPLAY_FLEET_COUNTER_NAMES)
                   - (replay_keys | fleet_keys))
    if extra:
        findings.append(Finding(
            path, line, "audit-counters",
            f"REPLAY_FLEET_COUNTER_NAMES contains {extra} which neither "
            f"replay nor fleet reports emit"))
    return findings


# --------------------------------------------------------------------------
# registry names
# --------------------------------------------------------------------------

def audit_registry_names() -> List[Finding]:
    from repro.envs import measure
    from repro.workloads import traces
    findings: List[Finding] = []

    path, line = _anchor(measure)
    for kind, shifts in measure.SHIFT_KINDS.items():
        if not NAME_RE.match(kind):
            findings.append(Finding(
                path, line, "audit-registry-names",
                f"shift kind {kind!r} is ill-formed (want {NAME_RE.pattern})"))
        if not shifts:
            findings.append(Finding(
                path, line, "audit-registry-names",
                f"shift kind {kind!r} maps to no EnvShift"))
    for name in measure.BACKEND_FACTORIES:
        if not NAME_RE.match(name):
            findings.append(Finding(
                path, line, "audit-registry-names",
                f"backend name {name!r} is ill-formed"))
    names = measure.backend_names()
    if len(set(names)) != len(names):
        findings.append(Finding(
            path, line, "audit-registry-names",
            f"backend_names() has duplicates: {sorted(names)}"))
    for name in names:
        base = name.split(":", 1)
        if not all(NAME_RE.match(part) for part in base):
            findings.append(Finding(
                path, line, "audit-registry-names",
                f"backend name {name!r} is ill-formed"))

    path, line = _anchor(traces)
    kinds = traces.workload_kinds()
    if len(set(kinds)) != len(kinds):
        findings.append(Finding(
            path, line, "audit-registry-names",
            f"workload kinds have duplicates: {sorted(kinds)}"))
    for kind in kinds:
        if not NAME_RE.match(kind):
            findings.append(Finding(
                path, line, "audit-registry-names",
                f"workload kind {kind!r} is ill-formed"))
    return findings


def run_audits() -> List[Finding]:
    findings: List[Finding] = []
    findings += audit_family_registration()
    findings += audit_option_spaces()
    findings += audit_counters()
    findings += audit_registry_names()
    return sorted(set(findings))
