"""AST contract linter: the repo's standing contracts as machine checks.

Rules (ids in :data:`repro.analysis.engine.RULES`):

- ``pallas-tpu-outside-compat`` — ``jax.experimental.pallas.tpu`` (imports
  or attribute chains, including ``pl.tpu`` through an alias) anywhere but
  ``compat.py``.  The compat layer is the single place version-gated TPU
  API lives.
- ``pallas-import-location`` — plain pallas imports are legal only in
  ``compat.py`` and ``kernels/*/kernel.py``; everything else must go
  through the dispatch registry.
- ``sharding-version-gate`` — ``getattr``/``hasattr`` probing on ``jax`` /
  ``jax.sharding`` outside ``compat.py`` (add a shim instead).
- ``unseeded-randomness`` — ``np.random.<fn>`` module-level sampler calls,
  argless ``default_rng()``, and any stdlib ``random`` use.  Bit-exact
  replay parity is the repo's core test invariant; every RNG must be an
  explicitly seeded Generator.
- ``wall-clock`` — ``time.time`` / ``perf_counter`` / ``monotonic`` /
  ``datetime.now`` reads outside the allow-listed measurement/trace
  modules.
- ``broad-except`` — bare ``except`` or catching ``Exception`` /
  ``BaseException``.
- ``span-balance`` — ``async_begin`` without a matching ``async_end`` in
  the same module, and ``.span(...)`` handles that are created but never
  entered (assigned and never used in a ``with``, or discarded as a bare
  expression statement).
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.engine import ERROR, Finding, norm_path

# Modules whose business IS reading the clock: the tracer, the measurement
# backends, replay/scheduler wall accounting, dispatch profiling, the
# benchmark/runner harnesses, run logging, and the compile-sweep dry-runner.
WALLCLOCK_ALLOWED = (
    "repro/obs/trace.py",
    "repro/envs/measure.py",
    "repro/serving/replay.py",
    "repro/serving/scheduler.py",
    "repro/kernels/dispatch.py",
    "repro/runtime/driver.py",
    "repro/utils/logging.py",
    "repro/tuner/bench.py",
    "repro/tuner/runner.py",
    "repro/launch/dryrun.py",
)

COMPAT_SUFFIX = "repro/compat.py"
_KERNEL_FILE_RE = re.compile(r"repro/kernels/[^/]+/kernel\.py$")

# numpy.random module-level samplers/state (the legacy global RNG surface)
NP_GLOBAL_SAMPLERS = frozenset({
    "rand", "randn", "randint", "random", "random_sample", "ranf", "sample",
    "choice", "shuffle", "permutation", "bytes", "uniform", "normal",
    "standard_normal", "poisson", "exponential", "beta", "binomial", "gamma",
    "geometric", "gumbel", "laplace", "logistic", "lognormal", "multinomial",
    "multivariate_normal", "pareto", "rayleigh", "triangular", "vonmises",
    "wald", "weibull", "zipf", "seed", "get_state", "set_state",
})

WALLCLOCK_TIME_FNS = frozenset({
    "time", "time_ns", "perf_counter", "perf_counter_ns",
    "monotonic", "monotonic_ns", "clock_gettime",
})


def _is_compat(path: str) -> bool:
    return path.endswith(COMPAT_SUFFIX)


def _pallas_import_ok(path: str) -> bool:
    return _is_compat(path) or _KERNEL_FILE_RE.search(path) is not None


def _wallclock_ok(path: str) -> bool:
    return any(path.endswith(s) for s in WALLCLOCK_ALLOWED)


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` attribute chain as a string, or None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []
        self.pallas_aliases: Set[str] = set()     # names bound to the pallas module
        self.numpy_aliases: Set[str] = set()      # names bound to numpy
        self.np_random_aliases: Set[str] = set()  # names bound to numpy.random
        self.time_aliases: Set[str] = set()       # names bound to time module
        self.time_fn_names: Set[str] = set()      # from time import perf_counter
        self.random_aliases: Set[str] = set()     # names bound to stdlib random
        self.random_fn_names: Set[str] = set()    # from random import choice
        self.default_rng_names: Set[str] = set()  # from numpy.random import default_rng
        self.datetime_names: Set[str] = set()     # datetime module/class names

    def flag(self, node: ast.AST, rule: str, message: str) -> None:
        self.findings.append(Finding(self.path, getattr(node, "lineno", 1),
                                     rule, message, ERROR))

    # -- imports ----------------------------------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            name, bound = alias.name, alias.asname or alias.name.split(".")[0]
            if name.startswith("jax.experimental.pallas.tpu"):
                if not _is_compat(self.path):
                    self.flag(node, "pallas-tpu-outside-compat",
                              f"import of {name} outside compat.py")
            elif name.startswith("jax.experimental.pallas"):
                if alias.asname:
                    self.pallas_aliases.add(bound)
                if not _pallas_import_ok(self.path):
                    self.flag(node, "pallas-import-location",
                              f"import of {name} outside compat.py / "
                              f"kernels/*/kernel.py — dispatch through the "
                              f"kernel registry instead")
            elif name == "numpy" or name.startswith("numpy."):
                self.numpy_aliases.add(bound)
            elif name == "time":
                self.time_aliases.add(bound)
            elif name == "random":
                self.random_aliases.add(bound)
                self.flag(node, "unseeded-randomness",
                          "stdlib random imported — use a seeded numpy "
                          "default_rng(seed)")
            elif name == "datetime":
                self.datetime_names.add(bound)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        names = {a.name: (a.asname or a.name) for a in node.names}
        if mod.startswith("jax.experimental.pallas.tpu"):
            if not _is_compat(self.path):
                self.flag(node, "pallas-tpu-outside-compat",
                          f"import from {mod} outside compat.py")
        elif mod == "jax.experimental" and "pallas" in names:
            self.pallas_aliases.add(names["pallas"])
            if not _pallas_import_ok(self.path):
                self.flag(node, "pallas-import-location",
                          "pallas imported outside compat.py / "
                          "kernels/*/kernel.py — dispatch through the "
                          "kernel registry instead")
        elif mod == "jax.experimental.pallas":
            if "tpu" in names and not _is_compat(self.path):
                self.flag(node, "pallas-tpu-outside-compat",
                          "pallas.tpu imported outside compat.py")
            elif not _pallas_import_ok(self.path):
                self.flag(node, "pallas-import-location",
                          "pallas imported outside compat.py / "
                          "kernels/*/kernel.py")
        elif mod in ("numpy.random", "numpy"):
            if mod == "numpy" and "random" in names:
                self.np_random_aliases.add(names["random"])
            if "default_rng" in names:
                self.default_rng_names.add(names["default_rng"])
            for name, bound in names.items():
                if mod == "numpy.random" and name in NP_GLOBAL_SAMPLERS:
                    self.flag(node, "unseeded-randomness",
                              f"numpy.random.{name} (global-RNG sampler) "
                              f"imported — use a seeded default_rng(seed)")
        elif mod == "time":
            for name, bound in names.items():
                if name in WALLCLOCK_TIME_FNS:
                    self.time_fn_names.add(bound)
        elif mod == "random":
            self.flag(node, "unseeded-randomness",
                      "stdlib random imported — use a seeded "
                      "numpy default_rng(seed)")
            self.random_fn_names.update(names.values())
        elif mod == "datetime":
            if "datetime" in names:
                self.datetime_names.add(names["datetime"])
        self.generic_visit(node)

    # -- expressions ------------------------------------------------------

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if not _is_compat(self.path):
            chain = _dotted(node)
            if chain and (".pallas.tpu" in chain or chain == "pallas.tpu"):
                self.flag(node, "pallas-tpu-outside-compat",
                          f"attribute chain {chain} outside compat.py")
            elif (node.attr == "tpu" and isinstance(node.value, ast.Name)
                  and node.value.id in self.pallas_aliases):
                self.flag(node, "pallas-tpu-outside-compat",
                          f"{node.value.id}.tpu (pallas.tpu through alias) "
                          f"outside compat.py")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        chain = _dotted(fn)

        # version-gate probing on jax outside compat
        if (isinstance(fn, ast.Name) and fn.id in ("getattr", "hasattr")
                and node.args and not _is_compat(self.path)):
            target = _dotted(node.args[0])
            if target == "jax" or (target or "").startswith("jax."):
                self.flag(node, "sharding-version-gate",
                          f"{fn.id}({target}, ...) version gate outside "
                          f"compat.py — add a compat shim")

        # unseeded randomness
        if chain:
            head, _, tail = chain.rpartition(".")
            if tail in NP_GLOBAL_SAMPLERS and head and (
                    head in self.np_random_aliases
                    or any(head == f"{np}.random" for np in self.numpy_aliases)):
                self.flag(node, "unseeded-randomness",
                          f"{chain}() uses the numpy global RNG — use a "
                          f"seeded default_rng(seed)")
            if head and (head in self.random_aliases):
                self.flag(node, "unseeded-randomness",
                          f"stdlib {chain}() — use a seeded numpy "
                          f"default_rng(seed)")
        if isinstance(fn, ast.Name) and fn.id in self.random_fn_names:
            self.flag(node, "unseeded-randomness",
                      f"stdlib random.{fn.id}() — use a seeded numpy "
                      f"default_rng(seed)")
        is_default_rng = (
            (chain and chain.split(".")[-1] == "default_rng")
            or (isinstance(fn, ast.Name) and fn.id in self.default_rng_names))
        if is_default_rng and not node.args and not node.keywords:
            self.flag(node, "unseeded-randomness",
                      "default_rng() without a seed draws OS entropy — pass "
                      "an explicit seed")

        # wall clock
        if not _wallclock_ok(self.path):
            if chain:
                head, _, tail = chain.rpartition(".")
                if head in self.time_aliases and tail in WALLCLOCK_TIME_FNS:
                    self.flag(node, "wall-clock",
                              f"{chain}() outside the measurement/trace "
                              f"allow-list")
                elif (tail in ("now", "utcnow", "today")
                      and head and head.split(".")[-1] in self.datetime_names):
                    self.flag(node, "wall-clock",
                              f"{chain}() outside the measurement/trace "
                              f"allow-list")
            if isinstance(fn, ast.Name) and fn.id in self.time_fn_names:
                self.flag(node, "wall-clock",
                          f"{fn.id}() outside the measurement/trace "
                          f"allow-list")
        self.generic_visit(node)

    def visit_ExceptHandler(self, node: ast.ExceptHandler) -> None:
        if node.type is None:
            self.flag(node, "broad-except",
                      "bare except: — name the exception types")
        else:
            broad = sorted({
                n.id if isinstance(n, ast.Name) else n.attr
                for n in ast.walk(node.type)
                if (isinstance(n, ast.Name)
                    and n.id in ("Exception", "BaseException"))
                or (isinstance(n, ast.Attribute)
                    and n.attr in ("Exception", "BaseException"))})
            if broad:
                self.flag(node, "broad-except",
                          f"except {'/'.join(broad)} — narrow to the "
                          f"exception types this block can actually handle")
        self.generic_visit(node)


# --------------------------------------------------------------------------
# span balance (module-level pass: needs begin/end pairing across functions)
# --------------------------------------------------------------------------

def _span_balance(tree: ast.Module, path: str) -> List[Finding]:
    findings: List[Finding] = []

    begins: List[Tuple[str, int]] = []
    ends: Set[str] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        if node.func.attr in ("async_begin", "async_end"):
            if node.args and isinstance(node.args[0], ast.Constant) \
                    and isinstance(node.args[0].value, str):
                name = node.args[0].value
                if node.func.attr == "async_begin":
                    begins.append((name, node.lineno))
                else:
                    ends.add(name)
    for name, line in begins:
        if name not in ends:
            findings.append(Finding(
                path, line, "span-balance",
                f'async_begin("{name}") has no matching async_end in this '
                f"module"))

    def _is_span_call(value: ast.expr) -> bool:
        return (isinstance(value, ast.Call)
                and isinstance(value.func, ast.Attribute)
                and value.func.attr == "span")

    scopes: List[ast.AST] = [tree]
    scopes += [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for scope in scopes:
        assigned: List[Tuple[str, int]] = []
        entered: Set[str] = set()
        for node in ast.walk(scope if not isinstance(scope, ast.Module)
                             else tree):
            if isinstance(node, ast.Assign) and _is_span_call(node.value) \
                    and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                assigned.append((node.targets[0].id, node.lineno))
            elif isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    if isinstance(item.context_expr, ast.Name):
                        entered.add(item.context_expr.id)
            elif isinstance(node, ast.Expr) and _is_span_call(node.value):
                findings.append(Finding(
                    path, node.lineno, "span-balance",
                    "span created and discarded — enter it with `with` or "
                    "keep the handle and close it"))
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Attribute)
                  and node.func.attr in ("__enter__", "__exit__")
                  and isinstance(node.func.value, ast.Name)):
                entered.add(node.func.value.id)
        if isinstance(scope, ast.Module):
            # module scope: only statements directly at top level
            assigned = [(n, l) for n, l in assigned
                        if any(isinstance(s, ast.Assign) and s.lineno == l
                               for s in tree.body)]
        for name, line in assigned:
            if name not in entered:
                findings.append(Finding(
                    path, line, "span-balance",
                    f"span handle {name!r} assigned but never entered "
                    f"(`with {name}:`)"))
    # deduplicate: nested function scopes are walked twice (module + self)
    return sorted(set(findings))


def lint_file(path: str) -> List[Finding]:
    path = norm_path(path)
    try:
        with open(path) as f:
            source = f.read()
    except OSError as e:
        return [Finding(path, 1, "parse-error", f"unreadable: {e}")]
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, "parse-error",
                        f"syntax error: {e.msg}")]
    linter = _Linter(path)
    linter.visit(tree)
    return sorted(set(linter.findings + _span_balance(tree, path)))
