"""Finding/suppression/baseline machinery for ``repro.analysis``.

The engine owns everything rule-independent: the :class:`Finding` record,
inline suppression comments, the grandfathering baseline, and the
orchestration that runs the three check layers (AST contract lint, pallas
kernel safety, registry audits) over a file set and reduces their raw
findings to the gated set.

Suppression syntax (the comment must sit on the finding's line or the line
directly above, and the justification after ``--`` is mandatory)::

    except BaseException as e:  # repro: ignore[broad-except] -- stored and re-raised on wait()

Baseline: ``analysis_baseline.json`` grandfathers known findings by exact
(path, line, rule, message) key.  A baseline entry that no longer matches
anything is itself a gate failure (``stale-baseline``) — fixed findings must
be removed by regenerating with ``--write-baseline``, so the baseline can
only shrink deliberately.
"""

from __future__ import annotations

import io
import json
import os
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

ERROR = "error"
WARNING = "warning"

#: rule id -> (default severity, one-line description).  Every Finding.rule
#: and every ``ignore[<rule>]`` target must be registered here.
RULES: Dict[str, Tuple[str, str]] = {
    # contract lint (repro.analysis.contracts)
    "pallas-tpu-outside-compat": (
        ERROR, "jax.experimental.pallas.tpu touched outside compat.py"),
    "pallas-import-location": (
        ERROR, "pallas imported outside compat.py / kernels/*/kernel.py"),
    "sharding-version-gate": (
        ERROR, "version-gated getattr/hasattr jax lookup outside compat.py"),
    "unseeded-randomness": (
        ERROR, "np.random module call, argless default_rng(), or stdlib "
               "random use (breaks bit-exact replay parity)"),
    "wall-clock": (
        ERROR, "wall-clock read outside the allow-listed measurement/trace "
               "modules"),
    "broad-except": (
        ERROR, "bare except / except Exception / except BaseException"),
    "span-balance": (
        ERROR, "tracer span opened via non-contextmanager API without a "
               "matching end"),
    "parse-error": (ERROR, "file failed to parse/tokenize"),
    # pallas kernel safety (repro.analysis.kernels)
    "kernel-write-race": (
        ERROR, "two grid points on a parallel dimension map to the same "
               "output block"),
    "kernel-vmem-budget": (
        ERROR, "static VMEM footprint exceeds the hardware budget for a "
               "launch config the analytic feasibility gate admits"),
    "kernel-signature": (
        ERROR, "pallas/ref signature, dtype, or shape contract mismatch"),
    "kernel-option-unused": (
        ERROR, "registered launch Option not accepted by the pallas or ref "
               "implementation"),
    "kernel-unanalyzable": (
        WARNING, "pallas_call structure could not be reconstructed "
                 "statically"),
    # registry audits (repro.analysis.audits)
    "audit-family-registration": (
        ERROR, "kernels/<family>/ directory not registered in dispatch.py "
               "or registered without launch Options"),
    "audit-option-space": (
        ERROR, "launch/serving ConfigSpace malformed (duplicate or "
               "ill-formed Option names, default outside domain)"),
    "audit-counters": (
        ERROR, "sim/fleet/replay counter emitted without a "
               "repro.obs.metrics declaration (or declared but not emitted)"),
    "audit-registry-names": (
        ERROR, "SHIFT_KINDS / workload / backend registry name ill-formed"),
    # suppression / baseline hygiene (this module)
    "suppression-syntax": (
        ERROR, "malformed suppression comment (missing -- reason or unknown "
               "rule id)"),
    "unused-suppression": (
        ERROR, "suppression comment matches no finding"),
    "stale-baseline": (
        ERROR, "baseline entry no longer matches any finding; regenerate "
               "with --write-baseline"),
}


@dataclass(frozen=True, order=True)
class Finding:
    """One violation at one location."""

    path: str
    line: int
    rule: str
    message: str
    severity: str = ERROR

    @property
    def key(self) -> Tuple[str, int, str, str]:
        return (self.path, self.line, self.rule, self.message)

    def to_dict(self) -> Dict[str, object]:
        return {"path": self.path, "line": self.line, "rule": self.rule,
                "message": self.message, "severity": self.severity}

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "Finding":
        return cls(path=str(d["path"]), line=int(d["line"]),  # type: ignore[arg-type]
                   rule=str(d["rule"]), message=str(d["message"]),
                   severity=str(d.get("severity", ERROR)))


def norm_path(path: str) -> str:
    """Repo-relative forward-slash path (what findings/suppressions key on)."""
    rel = os.path.relpath(path) if os.path.isabs(path) else path
    if rel.startswith(".." + os.sep) or rel == "..":
        rel = path  # outside the tree: keep as given
    return rel.replace(os.sep, "/")


# --------------------------------------------------------------------------
# inline suppressions
# --------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(
    r"repro:\s*ignore\[([^\]]*)\]\s*(?:--\s*(.*\S))?\s*$")


@dataclass
class Suppression:
    line: int
    rules: Tuple[str, ...]
    reason: str
    used: bool = False


def parse_suppressions(source: str, path: str
                       ) -> Tuple[Dict[int, Suppression], List[Finding]]:
    """Extract ``# repro: ignore[rule] -- reason`` comments via tokenize.

    Only real COMMENT tokens count (a suppression-shaped string literal is
    not a suppression).  Returns ``{line: Suppression}`` plus syntax
    findings for malformed comments.
    """
    out: Dict[int, Suppression] = {}
    findings: List[Finding] = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return out, findings  # the lint layer reports the parse error
    for tok in tokens:
        if tok.type != tokenize.COMMENT or "repro:" not in tok.string:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        line = tok.start[0]
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        reason = (m.group(2) or "").strip()
        bad = [r for r in rules if r not in RULES]
        if not rules or bad:
            findings.append(Finding(
                path, line, "suppression-syntax",
                f"unknown rule id(s) {bad or ['<empty>']} in suppression; "
                f"known rules: python -m repro.analysis --list-rules"))
            continue
        if not reason:
            findings.append(Finding(
                path, line, "suppression-syntax",
                f"suppression for {list(rules)} is missing its justification "
                f"(`# repro: ignore[rule] -- <reason>`)"))
            continue
        out[line] = Suppression(line=line, rules=rules, reason=reason)
    return out, findings


# --------------------------------------------------------------------------
# baseline
# --------------------------------------------------------------------------

BASELINE_VERSION = 1
DEFAULT_BASELINE = "analysis_baseline.json"


def load_baseline(path: Optional[str]) -> List[Finding]:
    if not path or not os.path.exists(path):
        return []
    with open(path) as f:
        doc = json.load(f)
    return [Finding.from_dict(d) for d in doc.get("findings", ())]


def write_baseline(findings: Sequence[Finding], path: str) -> None:
    doc = {"version": BASELINE_VERSION,
           "findings": [f.to_dict() for f in sorted(findings)]}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")


# --------------------------------------------------------------------------
# orchestration
# --------------------------------------------------------------------------

@dataclass
class Report:
    """Everything one analysis run produced, pre-sorted for rendering."""

    findings: List[Finding] = field(default_factory=list)   # active (gate)
    suppressed: List[Tuple[Finding, str]] = field(default_factory=list)
    grandfathered: List[Finding] = field(default_factory=list)
    files_scanned: int = 0
    configs_checked: int = 0  # kernel launch configs VMEM-validated

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == WARNING]

    @property
    def gate_ok(self) -> bool:
        return not self.errors


def discover_files(paths: Iterable[str]) -> List[str]:
    """All ``.py`` files under ``paths`` (files taken as-is), normalized."""
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(norm_path(p))
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d != "__pycache__"
                                 and not d.startswith("."))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(norm_path(os.path.join(dirpath, fn)))
    return sorted(set(out))


def _apply_suppressions(raw: List[Finding], files: Iterable[str],
                        report_unused: bool) -> Report:
    """Split raw findings into active vs inline-suppressed."""
    # parse suppressions for every file that is scanned OR carries a finding
    paths = set(files) | {f.path for f in raw}
    supp: Dict[str, Dict[int, Suppression]] = {}
    syntax: List[Finding] = []
    for path in sorted(paths):
        try:
            with open(path) as fh:
                source = fh.read()
        except OSError:
            continue
        supp[path], bad = parse_suppressions(source, path)
        syntax.extend(bad)

    rep = Report()
    for f in raw:
        smap = supp.get(f.path, {})
        hit = None
        for line in (f.line, f.line - 1):
            s = smap.get(line)
            if s is not None and f.rule in s.rules:
                hit = s
                break
        if hit is not None:
            hit.used = True
            rep.suppressed.append((f, hit.reason))
        else:
            rep.findings.append(f)
    rep.findings.extend(syntax)
    if report_unused:
        for path in sorted(supp):
            for s in supp[path].values():
                if not s.used:
                    rep.findings.append(Finding(
                        path, s.line, "unused-suppression",
                        f"suppression for {list(s.rules)} matches no "
                        f"finding — remove it"))
    return rep


def run_analysis(paths: Sequence[str] = ("src",), *, lint: bool = True,
                 kernels: bool = True, audits: bool = True,
                 baseline_path: Optional[str] = None) -> Report:
    """Run the enabled check layers and reduce to a gate-ready report."""
    files = discover_files(paths)
    raw: List[Finding] = []
    configs_checked = 0
    if lint:
        from repro.analysis import contracts
        for path in files:
            raw.extend(contracts.lint_file(path))
    if kernels:
        from repro.analysis import kernels as kernel_checks
        kfindings, configs_checked = kernel_checks.check_registered_families()
        raw.extend(kfindings)
    if audits:
        from repro.analysis import audits as audit_checks
        raw.extend(audit_checks.run_audits())

    # unused-suppression detection needs the full rule surface live —
    # a partial run (--no-kernels etc.) would misread layer-specific
    # suppressions as dead
    rep = _apply_suppressions(raw, files, report_unused=(lint and kernels
                                                         and audits))
    rep.files_scanned = len(files)
    rep.configs_checked = configs_checked

    baseline = load_baseline(baseline_path)
    if baseline:
        known = {f.key: False for f in baseline}
        active: List[Finding] = []
        for f in rep.findings:
            if f.key in known:
                known[f.key] = True
                rep.grandfathered.append(f)
            else:
                active.append(f)
        rep.findings = active
        for f in baseline:
            if not known[f.key]:
                rep.findings.append(Finding(
                    norm_path(baseline_path or DEFAULT_BASELINE), 1,
                    "stale-baseline",
                    f"baseline entry {f.path}:{f.line} [{f.rule}] no longer "
                    f"matches any finding; regenerate with --write-baseline"))
    rep.findings.sort()
    rep.suppressed.sort(key=lambda pair: pair[0])
    rep.grandfathered.sort()
    return rep
