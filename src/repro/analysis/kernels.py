"""Pallas kernel safety checker: static analysis of ``pl.pallas_call`` sites.

Registry-driven: :func:`check_registered_families` walks every family in
``repro.kernels.dispatch``, parses the kernel module's AST, reconstructs the
grid / BlockSpec / index-map structure of each ``pl.pallas_call`` (including
sites routed through a ``compat.prefetch_scalar_grid_spec`` local), and runs
three checks — a new family registered in dispatch gets all of them with
zero analyzer changes:

(a) **write races** (``kernel-write-race``): every out-spec index map is
    enumerated over a small concrete grid.  Two grid points that differ on
    a *parallel* grid dimension but land on the same output block race; grid
    points differing only on sequential ("arbitrary") dimensions are the
    legal accumulate-in-scratch pattern and do not fire.

(b) **VMEM footprint** (``kernel-vmem-budget``): for every launch config in
    the family's registered ``Option`` domains, a static footprint
    — 2x double-buffered in/out blocks at bf16 plus fp32 scratch — is
    cross-checked against the :class:`repro.utils.hardware.HardwareSpec`
    budget and the analytic feasibility gate
    (:class:`repro.envs.measure.LaunchGeometry`).  A config the analytic
    gate would admit but whose static footprint exceeds hardware VMEM is a
    gate miss: ``dispatch.launch_space()`` bounds must never allow it.

(c) **signature contracts** (``kernel-signature`` / ``kernel-option-unused``):
    pallas and ref entry points (variants included) must agree on required
    positional names and return annotations, the pallas impl must accept
    ``interpret``, and every registered launch ``Option`` must land on a
    real parameter of some implementation.

Index maps and block shapes are evaluated by compiling the lambda / shape
expression with every free name pre-bound: closure shape variables default
to :data:`DEFAULT_DIM` (block shapes) or a small constant (index maps), and
scalar-prefetch table refs are stubbed so subscripts like ``tbl[ib, ip]``
resolve.  Anything that still defeats evaluation degrades to the
non-gating ``kernel-unanalyzable`` warning rather than a false positive.
"""

from __future__ import annotations

import ast
import importlib.util
import inspect
import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.engine import ERROR, WARNING, Finding, norm_path

DEFAULT_DIM = 128     # free shape variables (head_dim, d_model slices, ...)
DEFAULT_INDEX = 2     # free closure scalars inside index maps (GQA group, ...)
GRID_POINTS_PER_DIM = 3
BF16_BYTES = 2        # serving activations/KV are bf16-class
DOUBLE_BUFFER = 2     # pallas pipelines in/out blocks double-buffered

DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool_": 1, "bool": 1,
}


class _FakeRef:
    """Stands in for scalar-prefetch refs inside index maps: any subscript
    (``tbl[ib, ip]``) resolves to block 0, which is what the race check
    wants — a table-driven map aliases maximally when the table is
    constant."""

    def __getitem__(self, _key: Any) -> int:
        return 0


@dataclass
class BlockSpecInfo:
    shape: Optional[ast.expr]          # block_shape tuple expression
    index_map: Optional[ast.Lambda]    # index map lambda (None = identity)
    line: int


@dataclass
class PallasCallSite:
    """One reconstructed ``pl.pallas_call`` launch."""

    path: str
    line: int
    grid: Optional[Tuple[ast.expr, ...]]         # one expr per grid dim
    in_specs: List[BlockSpecInfo] = field(default_factory=list)
    out_specs: List[BlockSpecInfo] = field(default_factory=list)
    scratch: List[Tuple[ast.expr, str]] = field(default_factory=list)
    dimension_semantics: Optional[Tuple[str, ...]] = None
    num_scalar_prefetch: int = 0


# --------------------------------------------------------------------------
# AST reconstruction
# --------------------------------------------------------------------------

def _call_name(call: ast.Call) -> str:
    fn = call.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return ""


def _kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _resolve(node: Optional[ast.expr],
             assigns: Dict[str, List[ast.expr]]) -> List[ast.expr]:
    """A value expression, following one level of local ``name = expr``
    assignment; multiple assignments (branchy code) yield every
    alternative."""
    if node is None:
        return []
    if isinstance(node, ast.Name) and node.id in assigns:
        return list(assigns[node.id])
    return [node]


def _block_spec(call: ast.expr) -> Optional[BlockSpecInfo]:
    if not (isinstance(call, ast.Call)
            and _call_name(call).endswith("BlockSpec")):
        return None
    shape = call.args[0] if call.args else _kwarg(call, "block_shape")
    imap = call.args[1] if len(call.args) > 1 else _kwarg(call, "index_map")
    return BlockSpecInfo(
        shape=shape,
        index_map=imap if isinstance(imap, ast.Lambda) else None,
        line=call.lineno)


def _spec_list(node: Optional[ast.expr],
               assigns: Dict[str, List[ast.expr]]) -> List[BlockSpecInfo]:
    out: List[BlockSpecInfo] = []
    for alt in _resolve(node, assigns):
        elts = alt.elts if isinstance(alt, (ast.List, ast.Tuple)) else [alt]
        for e in elts:
            spec = _block_spec(e)
            if spec is not None:
                out.append(spec)
    return out


def _scratch_list(node: Optional[ast.expr],
                  assigns: Dict[str, List[ast.expr]]
                  ) -> List[Tuple[ast.expr, str]]:
    out: List[Tuple[ast.expr, str]] = []
    for alt in _resolve(node, assigns):
        elts = alt.elts if isinstance(alt, (ast.List, ast.Tuple)) else [alt]
        for e in elts:
            if isinstance(e, ast.Call) and e.args:
                dtype = "float32"
                if len(e.args) > 1:
                    d = e.args[1]
                    dtype = d.attr if isinstance(d, ast.Attribute) else (
                        d.id if isinstance(d, ast.Name) else "float32")
                out.append((e.args[0], dtype))
    return out


def _grid_tuple(node: Optional[ast.expr],
                assigns: Dict[str, List[ast.expr]]
                ) -> Optional[Tuple[ast.expr, ...]]:
    for alt in _resolve(node, assigns):
        if isinstance(alt, (ast.Tuple, ast.List)):
            return tuple(alt.elts)
    return None


def _semantics(call: ast.Call,
               assigns: Dict[str, List[ast.expr]]
               ) -> Optional[Tuple[str, ...]]:
    node = _kwarg(call, "compiler_params")
    for alt in _resolve(node, assigns):
        if not isinstance(alt, ast.Call):
            continue
        sem = _kwarg(alt, "dimension_semantics")
        for s in _resolve(sem, assigns):
            if isinstance(s, (ast.Tuple, ast.List)):
                vals = [e.value for e in s.elts
                        if isinstance(e, ast.Constant)]
                if len(vals) == len(s.elts):
                    return tuple(vals)
    return None


def parse_kernel_source(source: str, path: str) -> List[PallasCallSite]:
    """Every ``pallas_call`` launch in a kernel module's source."""
    tree = ast.parse(source, filename=path)
    sites: List[PallasCallSite] = []
    seen_lines: set = set()
    funcs = [n for n in ast.walk(tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in funcs:
        assigns: Dict[str, List[ast.expr]] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                assigns.setdefault(node.targets[0].id, []).append(node.value)
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and _call_name(node) == "pallas_call"):
                continue
            if node.lineno in seen_lines:  # nested scopes walk nodes twice
                continue
            seen_lines.add(node.lineno)
            site = PallasCallSite(path=path, line=node.lineno, grid=None)
            containers: List[ast.Call] = [node]
            # grid_spec= routes grid/specs/scratch through a
            # prefetch_scalar_grid_spec (possibly a local assignment)
            for gs in _resolve(_kwarg(node, "grid_spec"), assigns):
                if isinstance(gs, ast.Call):
                    containers.append(gs)
                    nsp = _kwarg(gs, "num_scalar_prefetch")
                    if isinstance(nsp, ast.Constant):
                        site.num_scalar_prefetch = int(nsp.value)
            for c in containers:
                if site.grid is None:
                    site.grid = _grid_tuple(_kwarg(c, "grid"), assigns)
                site.in_specs += _spec_list(_kwarg(c, "in_specs"), assigns)
                site.out_specs += _spec_list(_kwarg(c, "out_specs"), assigns)
                site.scratch += _scratch_list(_kwarg(c, "scratch_shapes"),
                                              assigns)
            site.dimension_semantics = _semantics(node, assigns)
            sites.append(site)
    return sites


# --------------------------------------------------------------------------
# expression evaluation with defaulted free names
# --------------------------------------------------------------------------

def _free_names(node: ast.expr) -> List[str]:
    return sorted({n.id for n in ast.walk(node) if isinstance(n, ast.Name)})


def _eval_expr(node: ast.expr, bindings: Dict[str, Any], default: Any) -> Any:
    expr = ast.Expression(body=node)
    ast.fix_missing_locations(expr)
    env: Dict[str, Any] = {"__builtins__": {}}
    for name in _free_names(node):
        env[name] = bindings.get(name, default)
    return eval(compile(expr, "<repro.analysis>", "eval"), env)


def _compile_index_map(lam: ast.Lambda, bindings: Dict[str, Any]):
    """The index-map lambda as a callable; free closure names pre-bound."""
    params = {a.arg for a in lam.args.args}
    expr = ast.Expression(body=lam)
    ast.fix_missing_locations(expr)
    env: Dict[str, Any] = {"__builtins__": {}}
    for name in _free_names(lam.body):
        if name not in params:
            env[name] = bindings.get(name, DEFAULT_INDEX)
    return eval(compile(expr, "<repro.analysis>", "eval"), env), len(params)


# --------------------------------------------------------------------------
# (a) write races
# --------------------------------------------------------------------------

def race_findings(site: PallasCallSite,
                  bindings: Optional[Dict[str, Any]] = None) -> List[Finding]:
    """Enumerate every out-spec index map over a small concrete grid and
    flag output blocks reached from more than one parallel-dim
    coordinate."""
    if site.grid is None:
        return [Finding(site.path, site.line, "kernel-unanalyzable",
                        "grid could not be reconstructed statically",
                        WARNING)]
    ndim = len(site.grid)
    sem = site.dimension_semantics or ("parallel",) * ndim
    par_dims = [i for i in range(ndim)
                if i >= len(sem) or sem[i] == "parallel"]
    findings: List[Finding] = []
    for spec in site.out_specs:
        if spec.index_map is None:
            continue  # identity map: block i <- grid point i, race-free
        try:
            fn, arity = _compile_index_map(spec.index_map, bindings or {})
        except Exception:  # repro: ignore[broad-except] -- defensive eval wrapper: any failure degrades to the non-gating unanalyzable warning
            findings.append(Finding(
                site.path, spec.line, "kernel-unanalyzable",
                "out-spec index map could not be compiled", WARNING))
            continue
        extra = max(arity - ndim, 0)
        blocks: Dict[Tuple[Any, ...], set] = {}
        ok = True
        for pt in itertools.product(range(GRID_POINTS_PER_DIM), repeat=ndim):
            args = pt + tuple(_FakeRef() for _ in range(extra))
            try:
                block = fn(*args)
            except Exception:  # repro: ignore[broad-except] -- defensive eval wrapper: any failure degrades to the non-gating unanalyzable warning
                findings.append(Finding(
                    site.path, spec.line, "kernel-unanalyzable",
                    "out-spec index map evaluation failed", WARNING))
                ok = False
                break
            key = tuple(block) if isinstance(block, (tuple, list)) else (block,)
            proj = tuple(pt[i] for i in par_dims)
            blocks.setdefault(key, set()).add(proj)
        if not ok:
            continue
        raced = sorted(k for k, projs in blocks.items() if len(projs) > 1)
        if raced:
            findings.append(Finding(
                site.path, spec.line, "kernel-write-race",
                f"out-spec index map sends {len(raced)} distinct parallel "
                f"grid coordinates to the same output block (first: "
                f"{raced[0]}) — make the aliasing dimension sequential "
                f"('arbitrary') or fix the map"))
    return findings


# --------------------------------------------------------------------------
# (b) static VMEM footprint
# --------------------------------------------------------------------------

def _shape_bytes(shape_node: ast.expr, bindings: Dict[str, Any],
                 elem_bytes: int) -> int:
    dims = _eval_expr(shape_node, bindings, DEFAULT_DIM)
    if not isinstance(dims, (tuple, list)):
        dims = (dims,)
    total = elem_bytes
    for d in dims:
        total *= max(int(d), 1)
    return total


def static_vmem_bytes(site: PallasCallSite,
                      params: Optional[Dict[str, Any]] = None) -> int:
    """Conservative static VMEM estimate for one launch under ``params``:
    double-buffered bf16 in/out blocks plus scratch at its declared
    dtype.  Free shape names (data-dependent dims) default to
    :data:`DEFAULT_DIM`."""
    bindings = dict(params or {})
    total = 0
    for spec in site.in_specs + site.out_specs:
        if spec.shape is not None:
            total += DOUBLE_BUFFER * _shape_bytes(spec.shape, bindings,
                                                  BF16_BYTES)
    for shape_node, dtype in site.scratch:
        total += _shape_bytes(shape_node, bindings,
                              DTYPE_BYTES.get(dtype, 4))
    return total


def vmem_findings(sites: Sequence[PallasCallSite], family: str,
                  configs: Iterable[Dict[str, Any]], *,
                  vmem_budget: Optional[int] = None) -> Tuple[List[Finding], int]:
    """Cross-check every candidate launch config against the hardware VMEM
    budget AND the analytic feasibility gate.  Fires when the static
    footprint exceeds hardware VMEM for a config the analytic gate admits
    (or cannot see) — the gate-miss ``launch_space()`` must never allow."""
    from repro.utils.hardware import TPU_V5E
    budget = int(vmem_budget if vmem_budget is not None else
                 TPU_V5E.vmem_bytes)
    geometry = None
    try:
        from repro.envs.measure import KernelWorkload, LaunchGeometry
        if family in LaunchGeometry.MODELS:
            geometry = LaunchGeometry(KernelWorkload())
    except ImportError:
        pass
    findings: List[Finding] = []
    checked = 0
    for params in configs:
        checked += 1
        try:
            static = max((static_vmem_bytes(s, params) for s in sites),
                         default=0)
        except Exception:  # repro: ignore[broad-except] -- defensive eval wrapper: any failure degrades to the non-gating unanalyzable warning
            findings.append(Finding(
                sites[0].path if sites else f"<{family}>",
                sites[0].line if sites else 1, "kernel-unanalyzable",
                f"block shapes could not be evaluated for config {params}",
                WARNING))
            continue
        if static <= budget:
            continue
        gate_rejects = False
        if geometry is not None:
            vmem_analytic = geometry.family_cost(family, params)[2]
            gate_rejects = vmem_analytic > geometry.workload.vmem_limit
        if not gate_rejects:
            site = sites[0]
            findings.append(Finding(
                site.path, site.line, "kernel-vmem-budget",
                f"{family} config {dict(sorted(params.items()))}: static "
                f"VMEM footprint {static / 2**20:.1f} MiB exceeds the "
                f"{budget / 2**20:.0f} MiB hardware budget and the analytic "
                f"feasibility gate does not reject it — tighten the Option "
                f"domains in dispatch.py"))
    return sorted(set(findings)), checked


# --------------------------------------------------------------------------
# (c) signature contracts
# --------------------------------------------------------------------------

def _required_positional(fn) -> List[str]:
    sig = inspect.signature(fn)
    return [p.name for p in sig.parameters.values()
            if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
            and p.default is p.empty]


def _fn_anchor(fn) -> Tuple[str, int]:
    code = getattr(fn, "__wrapped__", fn).__code__
    return norm_path(code.co_filename), code.co_firstlineno


def signature_findings(family: str) -> List[Finding]:
    from repro.kernels import dispatch
    fam = dispatch.get_family(family)
    findings: List[Finding] = []
    entries = [(family, fam.pallas, fam.ref)]
    entries += [(f"{family}:{vname}", p, r)
                for vname, (p, r) in fam.variants]
    accepted: set = set()
    for label, pref, rref in entries:
        pfn, rfn = dispatch._load(pref), dispatch._load(rref)
        ppath, pline = _fn_anchor(pfn)
        psig, rsig = inspect.signature(pfn), inspect.signature(rfn)
        accepted |= set(psig.parameters) | set(rsig.parameters)
        preq, rreq = _required_positional(pfn), _required_positional(rfn)
        if preq != rreq:
            findings.append(Finding(
                ppath, pline, "kernel-signature",
                f"{label}: pallas required positionals {preq} != ref "
                f"required positionals {rreq} — dispatch passes one "
                f"argument list to both"))
        if "interpret" not in psig.parameters:
            findings.append(Finding(
                ppath, pline, "kernel-signature",
                f"{label}: pallas impl does not accept interpret= — the "
                f"pallas_interpret mode cannot route through it"))
        pret, rret = psig.return_annotation, rsig.return_annotation
        if (pret is not inspect.Signature.empty
                and rret is not inspect.Signature.empty and pret != rret):
            findings.append(Finding(
                ppath, pline, "kernel-signature",
                f"{label}: return annotation {pret} != ref's {rret}"))
    unused = [o.name for o in fam.launch_options if o.name not in accepted]
    if unused:
        path, line = _registration_anchor(family)
        findings.append(Finding(
            path, line, "kernel-option-unused",
            f"{family}: launch Option(s) {unused} are not parameters of any "
            f"pallas/ref implementation"))
    return findings


def _registration_anchor(family: str) -> Tuple[str, int]:
    from repro.kernels import dispatch
    path = norm_path(dispatch.__file__)
    try:
        with open(path) as f:
            for i, text in enumerate(f, 1):
                if f'name="{family}"' in text:
                    return path, i
    except OSError:
        pass
    return path, 1


# --------------------------------------------------------------------------
# registry-driven entry points
# --------------------------------------------------------------------------

def _family_sites(family: str) -> Tuple[List[PallasCallSite], List[Finding]]:
    from repro.kernels import dispatch
    fam = dispatch.get_family(family)
    module = fam.pallas.split(":")[0]
    spec = importlib.util.find_spec(module)
    if spec is None or not spec.origin:
        return [], [Finding(f"<{family}>", 1, "kernel-unanalyzable",
                            f"pallas module {module} not found", WARNING)]
    path = norm_path(spec.origin)
    try:
        with open(spec.origin) as f:
            source = f.read()
        return parse_kernel_source(source, path), []
    except (OSError, SyntaxError) as e:
        return [], [Finding(path, 1, "kernel-unanalyzable",
                            f"kernel module unparseable: {e}", WARNING)]


def option_configs(family: str) -> List[Dict[str, Any]]:
    """The full cartesian product of the family's registered Option
    domains — exactly the set ``dispatch.launch_space()`` can emit."""
    from repro.kernels import dispatch
    fam = dispatch.get_family(family)
    names = [o.name for o in fam.launch_options]
    domains = [o.values for o in fam.launch_options]
    return [dict(zip(names, combo))
            for combo in itertools.product(*domains)] if names else [{}]


def check_family(family: str, *,
                 vmem_budget: Optional[int] = None
                 ) -> Tuple[List[Finding], int]:
    """All three safety checks for one registered family."""
    sites, findings = _family_sites(family)
    for site in sites:
        findings.extend(race_findings(site))
    vfindings, checked = vmem_findings(sites, family, option_configs(family),
                                       vmem_budget=vmem_budget)
    findings.extend(vfindings)
    findings.extend(signature_findings(family))
    return sorted(set(findings)), checked


def check_registered_families() -> Tuple[List[Finding], int]:
    """Every family in the dispatch registry; returns (findings, total
    launch configs VMEM-validated)."""
    from repro.kernels import dispatch
    findings: List[Finding] = []
    checked = 0
    for family in dispatch.families():
        f, n = check_family(family)
        findings.extend(f)
        checked += n
    return findings, checked


def analyze_kernel_source(source: str, path: str = "<fixture>", *,
                          configs: Optional[Iterable[Dict[str, Any]]] = None,
                          family: str = "<fixture>",
                          vmem_budget: Optional[int] = None
                          ) -> List[Finding]:
    """Fixture-friendly: race + (optional) VMEM checks over raw kernel
    source, no registry required."""
    sites = parse_kernel_source(source, path)
    findings: List[Finding] = []
    for site in sites:
        findings.extend(race_findings(site))
    if configs is not None:
        vfindings, _ = vmem_findings(sites, family, configs,
                                     vmem_budget=vmem_budget)
        findings.extend(vfindings)
    return sorted(set(findings))
