"""Static analysis subsystem: contract linter + pallas kernel safety checker.

``python -m repro.analysis --gate src/`` is the CI entry point; see
:mod:`repro.analysis.engine` for the finding/suppression/baseline model,
:mod:`repro.analysis.contracts` for the AST lint rules,
:mod:`repro.analysis.kernels` for the pallas launch checks, and
:mod:`repro.analysis.audits` for the registry audits.
"""

from repro.analysis.engine import (  # noqa: F401
    ERROR, RULES, WARNING, Finding, Report, run_analysis)
