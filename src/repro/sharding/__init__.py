from repro.sharding.specs import (  # noqa: F401
    activation_sharding,
    param_specs,
    named_shardings,
    data_axes_of,
)
