"""PartitionSpec rules: how every parameter, activation, cache, and optimizer
slot shards over the production mesh.

Conventions
-----------
- data-like axes: ("pod", "data") when present — batch / FSDP / EP(optional)
- "model" axis — tensor parallelism (heads, d_ff, vocab, d_inner)
- parameters carry a leading super-block dim when scanned -> specs get a
  leading None
- FSDP (``par.fsdp > 1``) shards the *non-TP* matrix dimension of each weight
  over the data-like axes (ZeRO-3 style); optimizer state inherits the same
  spec.

Rules are keyed on parameter path suffixes; anything unmatched is replicated.
This table *is* part of the tunable surface: CAMEO mutates ``ParallelConfig``
and the rules react.
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.utils.config import ModelConfig, ParallelConfig


def data_axes_of(mesh_axes: Tuple[str, ...]) -> Tuple[str, ...]:
    return tuple(a for a in mesh_axes if a in ("pod", "data"))


def _active_mesh() -> Optional[Mesh]:
    # version-gated lookup (jax.sharding.get_abstract_mesh is 0.5+)
    return compat.get_abstract_mesh()


def activation_sharding(h: jax.Array, par: ParallelConfig) -> jax.Array:
    """Constrain (B, S, D) activations: batch over data axes, seq over model
    when sequence parallelism is on."""
    mesh = _active_mesh()
    if mesh is None:
        return h
    daxes = data_axes_of(tuple(mesh.axis_names))
    if not daxes:
        return h
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))
    batch_spec = daxes if (h.shape[0] % dsize == 0) else None
    seq_spec = None
    if par.sp and h.ndim >= 3 and "model" in mesh.axis_names \
            and h.shape[1] % mesh.shape["model"] == 0:
        seq_spec = "model"
    spec = P(batch_spec, seq_spec, *([None] * (h.ndim - 2)))
    return jax.lax.with_sharding_constraint(h, spec)


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

# (regex on path, spec builder(fsdp_axes) -> tuple of axis assignments for the
#  *trailing* dims of the weight; leading scan dim handled separately)
def _rules(par: ParallelConfig):
    F = "__FSDP__"  # placeholder replaced by fsdp axes (or None)
    M = "model" if par.tp > 1 else None
    E = "model" if par.moe_expert_axis == "model" else "__EP__"
    return [
        # embeddings / head
        (r"embed/embedding$", (M, F)),
        (r"embed/lm_head$", (F, M)),
        (r"frame_proj$", (F, M)),
        # attention (gqa & cross)
        (r"attn/wq$|cross/wq$", (F, M)),
        (r"attn/wk$|cross/wk$", (F, M)),
        (r"attn/wv$|cross/wv$", (F, M)),
        (r"attn/wo$|cross/wo$", (M, F)),
        # MLA
        (r"attn/w_dq$", (F, None)),
        (r"attn/w_uq$", (None, M)),
        (r"attn/w_dkv$", (F, None)),
        (r"attn/w_uk$", (None, M)),
        (r"attn/w_uv$", (None, M)),
        # dense mlp
        (r"mlp/w_gate$|mlp/w_up$|shared/w_gate$|shared/w_up$", (F, M)),
        (r"mlp/w_down$|shared/w_down$", (M, F)),
        # MoE experts (leading expert dim)
        (r"moe/router$", (F, None)),
        (r"moe/w_gate$|moe/w_up$", (E, F, M if E != "model" else None)),
        (r"moe/w_down$", (E, M if E != "model" else None, F)),
        # mamba1
        (r"mixer/w_x$|mixer/w_z$", (F, M)),
        (r"mixer/conv_w$|mixer/conv_x_w$", (None, M)),
        (r"mixer/conv_b$|mixer/conv_x_b$", (M,)),
        (r"mixer/w_bcdt$", (M, None)),
        (r"mixer/w_dt$", (None, M)),
        (r"mixer/dt_bias$", (M,)),
        (r"mixer/A_log$", (M, None)),
        (r"mixer/D$", (M,)),
        (r"mixer/w_out$", (M, F)),
        # mamba2 extras
        (r"mixer/w_B$|mixer/w_C$|mixer/w_dtp$", (F, None)),
        (r"mixer/conv_bc_w$|mixer/conv_bc_b$", None),  # tiny, replicate
        (r"mixer/norm_scale$", (M,)),
        # mtp
        (r"mtp/proj$", (F, M)),
        # norms: replicate
        (r"norm", None),
        (r"scale$", None),
        (r"cross_gate$", None),
    ]


def _spec_for(path: str, shape: Tuple[int, ...], cfg: ModelConfig,
              par: ParallelConfig, mesh_axes: Tuple[str, ...],
              mesh_shape: Dict[str, int]) -> P:
    daxes = data_axes_of(mesh_axes)
    fsdp_axes: Any = daxes if (par.fsdp > 1 and daxes) else None
    scanned = any(seg in path for seg in ("blocks/",))

    dims = len(shape)
    body_dims = dims - 1 if scanned else dims
    assign: Any = None
    for pat, spec in _rules(par):
        if re.search(pat, path):
            assign = spec
            break

    out = [None] * dims
    if assign is not None:
        # tail-align the assignment onto the body dims
        assign = list(assign)[-body_dims:] if body_dims else []
        offset = dims - len(assign)
        for i, a in enumerate(assign):
            if a == "__FSDP__":
                a = fsdp_axes
            elif a == "__EP__":
                a = daxes if daxes else None
            if a is None:
                continue
            axes = a if isinstance(a, tuple) else (a,)
            size = int(np.prod([mesh_shape.get(x, 1) for x in axes]))
            if size > 1 and shape[offset + i] % size == 0:
                out[offset + i] = a
    # drop duplicate axis uses (an axis may appear only once in a spec)
    seen = set()
    for i, a in enumerate(out):
        axes = a if isinstance(a, tuple) else (a,) if a else ()
        if any(x in seen for x in axes):
            out[i] = None
        else:
            seen.update(axes)
    return P(*out)


def param_specs(params_shapes, cfg: ModelConfig, par: ParallelConfig,
                mesh: Mesh) -> Any:
    """Tree of PartitionSpec matching a (possibly abstract) params tree."""
    mesh_axes = tuple(mesh.axis_names)
    mesh_shape = dict(mesh.shape)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(_key_str(p) for p in path)
        specs.append(_spec_for(pstr, tuple(leaf.shape), cfg, par, mesh_axes, mesh_shape))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named_shardings(params_shapes, cfg: ModelConfig, par: ParallelConfig,
                    mesh: Mesh) -> Any:
    specs = param_specs(params_shapes, cfg, par, mesh)
    return compat.tree_map(lambda s: NamedSharding(mesh, s), specs,
                           is_leaf=lambda x: isinstance(x, P))


def cache_specs(state_shapes, cfg: ModelConfig, par: ParallelConfig,
                mesh: Mesh) -> Any:
    """Decode-state sharding: batch over data axes (when divisible), kv-heads
    / latent dims over model axis where aligned."""
    mesh_axes = tuple(mesh.axis_names)
    mesh_shape = dict(mesh.shape)
    daxes = data_axes_of(mesh_axes)
    dsize = int(np.prod([mesh_shape[a] for a in daxes])) if daxes else 1
    msize = mesh_shape.get("model", 1)

    def one(path, leaf):
        shape = leaf.shape
        # stacked caches have a leading super-block dim
        # find batch dim: first dim (after optional stack dim) that divides
        out = [None] * len(shape)
        start = 1 if len(shape) >= 3 else 0
        if len(shape) >= 2 and daxes and shape[start] % dsize == 0:
            out[start] = daxes
        # shard a heads-like or channel dim over model (k/v: (..., S, H, D))
        pstr = "/".join(_key_str(p) for p in path)
        if msize > 1 and len(shape) >= 2:
            for d in range(len(shape) - 1, start, -1):
                if out[d] is None and shape[d] % msize == 0 and shape[d] >= msize:
                    if ("length" not in pstr):
                        out[d] = "model"
                        break
        return P(*out)

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shapes)
    return jax.tree_util.tree_unflatten(treedef, [one(p, l) for p, l in flat])


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "name"):
        return str(p.name)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


# --------------------------------------------------------------------------
# train / serve state + batch specs
# --------------------------------------------------------------------------

def _flat_by_path(tree) -> Dict[str, Any]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out["/".join(_key_str(p) for p in path)] = leaf
    return out


def train_state_specs(state_template, cfg: ModelConfig, par: ParallelConfig,
                      mesh: Mesh):
    """PartitionSpecs for a full TrainState (params + optimizer slots + step
    + error buffer).

    Optimizer slots inherit the parameter's spec; adafactor's factored
    ``vr``/``vc`` slots drop the corresponding spec dimension (vr drops the
    last, vc the second-to-last) so ZeRO-style sharding carries over to the
    factored statistics.
    """
    pspecs = param_specs(state_template.params, cfg, par, mesh)
    pspec_by_path = _flat_by_path(pspecs)

    def opt_spec(path: str, leaf) -> P:
        parts = path.split("/")
        if parts and parts[0] in ("m", "v"):
            return pspec_by_path.get("/".join(parts[1:]), P())
        if parts and parts[0] == "slots":
            kind = parts[-1]
            ppath = "/".join(parts[1:-1])
            spec = tuple(pspec_by_path.get(ppath, P()))
            # pad the spec with Nones to the param rank before factoring
            rank = len(leaf.shape) + (1 if kind in ("vr", "vc") else 0)
            spec = (None,) * (rank - len(spec)) + spec
            if kind == "vr":
                return P(*spec[:-1])
            if kind == "vc":
                return P(*(spec[:-2] + spec[-1:]))
            return P(*spec)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_template.opt_state)
    opt_specs = jax.tree_util.tree_unflatten(
        treedef,
        [opt_spec("/".join(_key_str(p) for p in path), leaf)
         for path, leaf in flat])

    err_specs = None
    if state_template.error_buf is not None:
        err_specs = pspecs
    return type(state_template)(
        params=pspecs, opt_state=opt_specs, step=P(), error_buf=err_specs)


def batch_specs(batch_template, mesh: Mesh):
    """Batch arrays shard dim 0 over the data-like axes."""
    daxes = data_axes_of(tuple(mesh.axis_names))
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1

    def one(leaf):
        if daxes and leaf.shape and leaf.shape[0] % dsize == 0:
            return P(daxes, *([None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    return compat.tree_map(one, batch_template)


def serve_state_specs(state_template, cfg: ModelConfig, par: ParallelConfig,
                      mesh: Mesh):
    """ServeState sharding: caches via cache rules; lengths/extras batch-major."""
    mesh_axes = tuple(mesh.axis_names)
    daxes = data_axes_of(mesh_axes)
    dsize = int(np.prod([dict(mesh.shape)[a] for a in daxes])) if daxes else 1

    caches = cache_specs(state_template.caches, cfg, par, mesh)
    lengths = (P(daxes) if daxes and state_template.lengths.shape[0] % dsize == 0
               else P(None))

    def extra_spec(leaf):
        out = [None] * len(leaf.shape)
        if daxes and leaf.shape and leaf.shape[0] % dsize == 0:
            out[0] = daxes
        msize = dict(mesh.shape).get("model", 1)
        if len(leaf.shape) >= 2 and msize > 1 and leaf.shape[-1] % msize == 0:
            out[-1] = "model"
        return P(*out)

    extras = compat.tree_map(extra_spec, state_template.extras)
    return type(state_template)(caches=caches, lengths=lengths, extras=extras)
