"""Structured run logging (stdout + JSONL metrics file).

``MetricsLogger`` is a context manager — ``with MetricsLogger(path=...) as
log:`` guarantees the JSONL handle is released on exceptions — and every
numeric metric it logs is mirrored into the process-wide observability
registry (:data:`repro.obs.metrics.REGISTRY`) as a gauge labelled with the
logger name, so ad-hoc training/serving loops feed the same snapshot
surface as the instrumented serving stack.  ``close()`` is idempotent.
"""

from __future__ import annotations

import json
import os
import sys
import time
from typing import Any, Dict, Optional

from repro.obs import metrics as obs_metrics


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, name: str = "run"):
        self.name = name
        self.path = path
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "a", buffering=1)
        self._t0 = time.time()

    def __enter__(self) -> "MetricsLogger":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def log(self, step: int, **metrics: Any) -> None:
        rec: Dict[str, Any] = {"step": step, "t": round(time.time() - self._t0, 3)}
        rec.update({k: (float(v) if hasattr(v, "item") else v) for k, v in metrics.items()})
        for k, v in rec.items():
            if k not in ("step", "t") and isinstance(v, (int, float)) \
                    and not isinstance(v, bool):
                try:
                    obs_metrics.REGISTRY.set(k, float(v), logger=self.name)
                except ValueError:
                    # name declared as a non-gauge elsewhere: logging must
                    # never fail over a registry kind collision
                    pass
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
        msg = " ".join(f"{k}={_fmt(v)}" for k, v in rec.items())
        print(f"[{self.name}] {msg}", file=sys.stderr)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return v
