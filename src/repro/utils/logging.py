"""Structured run logging (stdout + JSONL metrics file)."""

from __future__ import annotations

import json
import os
import sys
import time
from typing import Any, Dict, Optional


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, name: str = "run"):
        self.name = name
        self.path = path
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "a", buffering=1)
        self._t0 = time.time()

    def log(self, step: int, **metrics: Any) -> None:
        rec: Dict[str, Any] = {"step": step, "t": round(time.time() - self._t0, 3)}
        rec.update({k: (float(v) if hasattr(v, "item") else v) for k, v in metrics.items()})
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
        msg = " ".join(f"{k}={_fmt(v)}" for k, v in rec.items())
        print(f"[{self.name}] {msg}", file=sys.stderr)

    def close(self) -> None:
        if self._fh:
            self._fh.close()
            self._fh = None


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return v
