"""Small pytree helpers shared across train/checkpoint/runtime."""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def tree_size_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def flatten_with_paths(tree) -> Dict[str, Any]:
    """'a/b/0/c' -> leaf mapping, for checkpoint serialization."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol) for x, y in zip(la, lb))
