from repro.utils.config import (  # noqa: F401
    ModelConfig,
    MeshConfig,
    ParallelConfig,
    TrainConfig,
    RunConfig,
    frozen,
)
from repro.utils.hardware import HardwareSpec, TPU_V5E, TPU_V4_LIKE  # noqa: F401
