"""Hardware constants used by the roofline model and the analytic perf env.

TPU v5e is the primary target per the task spec; the "v4-like" variant exists
so the tuner has a *hardware change* environment axis (the paper's
TX2 -> Xavier move).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float      # FLOP/s per chip
    hbm_bandwidth: float        # bytes/s per chip
    hbm_capacity: float         # bytes per chip
    ici_bandwidth: float        # bytes/s per link (intra-pod)
    dci_bandwidth: float        # bytes/s per link (cross-pod / data-center)
    vmem_bytes: float = 128 * 2**20  # ~128 MiB VMEM per core (v5e-ish)
    ici_latency_us: float = 1.0
    dci_latency_us: float = 25.0

    def roofline_time(self, flops: float, hbm_bytes: float, coll_bytes: float,
                      chips: int, cross_pod: bool = False) -> dict:
        """Three-term roofline residence times in seconds (per the task spec)."""
        link = self.dci_bandwidth if cross_pod else self.ici_bandwidth
        return {
            "compute_s": flops / (chips * self.peak_flops_bf16),
            "memory_s": hbm_bytes / (chips * self.hbm_bandwidth),
            "collective_s": coll_bytes / (chips * link),
        }


# Task-spec constants: 197 TFLOP/s bf16; 819 GB/s HBM; ~50 GB/s/link ICI.
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    hbm_capacity=16 * 2**30,
    ici_bandwidth=50e9,
    dci_bandwidth=12.5e9,  # cross-pod links are ~4x thinner
)

# A "different hardware" environment for transfer experiments: more HBM bw,
# more capacity, different compute/comm balance (v4-like).
TPU_V4_LIKE = HardwareSpec(
    name="tpu_v4_like",
    peak_flops_bf16=275e12,
    hbm_bandwidth=1200e9,
    hbm_capacity=32 * 2**30,
    ici_bandwidth=100e9,
    dci_bandwidth=25e9,
)

HARDWARE = {h.name: h for h in (TPU_V5E, TPU_V4_LIKE)}
