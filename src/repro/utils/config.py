"""Config system.

Frozen dataclasses with ``replace``-style updates, dict round-trip (for
checkpoint metadata and launch scripts), and validation hooks.  Every model
architecture in ``repro.configs`` is a ``ModelConfig``; the launcher composes
``ModelConfig × ShapeConfig × ParallelConfig × TrainConfig`` into a
``RunConfig``.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple


def frozen(cls):
    """Decorator alias so configs read as ``@frozen`` like production code."""
    return dataclasses.dataclass(frozen=True)(cls)


def _asdict(obj) -> Dict[str, Any]:
    return dataclasses.asdict(obj)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description; superset of all 10 assigned families."""

    name: str = "tiny"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0  # 0 -> d_model // num_heads
    d_ff: int = 512
    vocab_size: int = 256
    max_seq_len: int = 2048

    # activation / norm
    mlp_type: str = "swiglu"  # swiglu | relu2 | gelu
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    attn_logit_softcap: float = 0.0

    # attention variants
    attn_type: str = "gqa"  # gqa | mla | swa | none
    sliding_window: int = 0  # >0 -> sliding-window attention
    # MLA (deepseek-style latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_num_shared: int = 0
    moe_layer_period: int = 1  # every k-th layer is MoE (llama4 interleaving)
    moe_capacity_factor: float = 1.25
    moe_router: str = "softmax"  # softmax | sigmoid (deepseek-v3)

    # SSM (mamba1 / mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_num_heads: int = 0  # mamba2 heads; 0 -> mamba1
    ssm_chunk: int = 256
    # hybrid: attention block applied every `hybrid_attn_period` layers,
    # sharing one set of weights (zamba2-style shared block).
    hybrid_attn_period: int = 0

    # VLM cross-attention
    cross_attn_period: int = 0  # every k-th layer has cross-attention
    vision_seq: int = 0  # number of patch embeddings (stub frontend)
    vision_dim: int = 0

    # audio enc-dec
    encoder_layers: int = 0
    encoder_seq: int = 0  # frames after conv frontend (stubbed)

    # MTP (deepseek multi-token prediction) — extra head depth
    mtp_depth: int = 0

    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived sizes ----------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.moe_num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.attn_type == "none" and self.hybrid_attn_period == 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can decode at 500k context with bounded state."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementation)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> Dict[str, Any]:
        return _asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ModelConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes


@dataclass(frozen=True)
class ParallelConfig:
    """Parallelism plan — the primary CAMEO-tunable surface."""

    dp: int = 1           # pure data parallel degree (within "data" axis)
    fsdp: int = 1         # parameter/optimizer sharding degree over data axis
    tp: int = 1           # tensor parallel degree over "model" axis
    ep: int = 1           # expert parallel degree (MoE; subdivides data axis)
    sp: bool = False      # sequence/context parallelism for activations
    microbatch: int = 1   # gradient-accumulation microbatches
    remat: str = "none"   # none | full | dots
    grad_compression: str = "none"  # none | int8_ef
    collective_matmul: bool = False  # decompose TP matmuls for overlap
    scan_layers: bool = True
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    decode_kv_shard: str = "model"  # axis KV cache is sharded over at decode
    moe_group_size: int = 512       # GShard routing group size
    moe_expert_axis: str = "model"  # model (TP-combine) | data (EP all-to-all)

    def replace(self, **kw) -> "ParallelConfig":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> Dict[str, Any]:
        return _asdict(self)


@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"  # adamw | adafactor | sgdm
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    schedule: str = "cosine"  # cosine | linear | constant
    seed: int = 0
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    z_loss: float = 1e-4
    moe_aux_loss: float = 1e-2

    def to_dict(self) -> Dict[str, Any]:
        return _asdict(self)


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    shape: ShapeConfig = field(default_factory=lambda: ShapeConfig("train_tiny", 128, 8, "train"))
    mesh: MeshConfig = field(default_factory=MeshConfig)
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 10

    def validate(self) -> None:
        m, p = self.mesh, self.parallel
        data_size = 1
        for ax, s in zip(m.axes, m.shape):
            if ax in ("data", "pod"):
                data_size *= s
        model_size = dict(zip(m.axes, m.shape)).get("model", 1)
        if p.tp > model_size:
            raise ValueError(f"tp={p.tp} exceeds model axis size {model_size}")
        if self.shape.global_batch % (data_size * p.microbatch) != 0 and self.shape.kind == "train":
            raise ValueError(
                f"global_batch={self.shape.global_batch} not divisible by "
                f"data axis ({data_size}) x microbatch ({p.microbatch})"
            )
        if self.model.is_moe and self.model.moe_num_experts % p.ep != 0:
            raise ValueError("experts not divisible by ep degree")

    def to_json(self) -> str:
        return json.dumps(
            {
                "model": self.model.to_dict(),
                "shape": _asdict(self.shape),
                "mesh": _asdict(self.mesh),
                "parallel": self.parallel.to_dict(),
                "train": self.train.to_dict(),
                "checkpoint_dir": self.checkpoint_dir,
                "checkpoint_every": self.checkpoint_every,
                "keep_checkpoints": self.keep_checkpoints,
            },
            indent=2,
        )

    @classmethod
    def from_json(cls, s: str) -> "RunConfig":
        d = json.loads(s)
        return cls(
            model=ModelConfig.from_dict(d["model"]),
            shape=ShapeConfig(**d["shape"]),
            mesh=MeshConfig(shape=tuple(d["mesh"]["shape"]), axes=tuple(d["mesh"]["axes"])),
            parallel=ParallelConfig(**d["parallel"]),
            train=TrainConfig(**d["train"]),
            checkpoint_dir=d.get("checkpoint_dir", "/tmp/repro_ckpt"),
            checkpoint_every=d.get("checkpoint_every", 100),
            keep_checkpoints=d.get("keep_checkpoints", 3),
        )

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
